//! The three detlint analyses: panic reachability, determinism
//! dataflow, and metric-plumbing consistency.
//!
//! Each check emits [`Finding`]s with a stable rule name; suppression
//! (`// srclint: allow(<rule>) — why` on the line or the line above,
//! or a file-scoped `// srclint: allow-file(<rule>) — why`) is applied
//! by the driver in [`crate::analysis`], not here, so the checks stay
//! pure functions from parsed sources to raw findings.

use std::collections::BTreeMap;

use super::callgraph::{FnId, Graph};
use super::lexer::allow_at;
use super::parse::{FieldDecl, Item, ItemKind};

/// One analysis finding.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Finding {
    pub file: String,
    pub line: usize,
    pub rule: &'static str,
    pub msg: String,
}

impl std::fmt::Display for Finding {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.msg)
    }
}

/// Rule names (kept as constants so tests and docs can't drift).
pub const RULE_PANIC: &str = "panic-reachable";
pub const RULE_INDEX: &str = "index-reachable";
pub const RULE_TRUNCATION: &str = "as-truncation";
pub const RULE_DISCARD: &str = "discarded-result";
pub const RULE_HASH_ITER: &str = "hash-iteration";
pub const RULE_FLOAT_SUM: &str = "float-sum-order";
pub const RULE_SPAWN: &str = "raw-spawn";
pub const RULE_CLOCK: &str = "clock-in-results";
pub const RULE_PLUMBING: &str = "metric-plumbing";

pub const ALL_RULES: &[&str] = &[
    RULE_PANIC,
    RULE_INDEX,
    RULE_TRUNCATION,
    RULE_DISCARD,
    RULE_HASH_ITER,
    RULE_FLOAT_SUM,
    RULE_SPAWN,
    RULE_CLOCK,
    RULE_PLUMBING,
];

/// Hot-path entry points: `(file suffix, fn-name glob)`.  Panic and
/// index reachability is computed from these roots.
pub const ENTRY_POINTS: &[(&str, &str)] = &[
    ("sim/engine.rs", "run*"),
    ("sim/dynamic.rs", "run_dynamic*"),
    ("coordinator/frontend.rs", "route*"),
    ("policy/grin.rs", "solve*"),
];

/// Struct literals that count as "result" constructions; fns that can
/// reach one of these feed the determinism-dataflow rules.
pub const RESULT_SINKS: &[&str] =
    &["SimResult", "DynCellStats", "CellStats", "DynamicReport"];

/// Files where `thread::spawn` is legitimate: the replicated-run
/// fan-out, the coordinator's worker pools, and the model checker's
/// schedule explorer.
pub const SPAWN_ALLOWED: &[&str] = &["sim/replicate.rs", "coordinator/", "sync/"];

/// Host-side tooling modules: never linked into the sim/serving core,
/// so they are excluded from the hot-path call graph (they would
/// otherwise be pulled in through method-name over-approximation).
pub const TOOLING: &[&str] = &["analysis/", "bin/", "lint.rs", "testkit/"];

/// Integer targets for which an `as` cast can silently truncate.
const NARROW_INTS: &[&str] = &["u8", "u16", "u32", "i8", "i16", "i32"];

/// Macros that unconditionally (or on failed invariant) panic.
const PANIC_MACROS: &[&str] = &["panic", "unreachable", "todo", "unimplemented"];

/// Methods that panic on the error/none case.
const PANIC_METHODS: &[&str] = &["unwrap", "expect"];

pub fn is_tooling(file: &str) -> bool {
    TOOLING.iter().any(|t| file.starts_with(t) || file == t.trim_end_matches('/'))
}

// ---------------------------------------------------------------------------
// Analysis 1: panic reachability
// ---------------------------------------------------------------------------

/// Interprocedural may-panic: BFS the call graph from the hot-path
/// entry points; every reached fn that contains a panic seed
/// (`unwrap`/`expect`/`panic!`-family) yields one aggregated
/// `panic-reachable` finding, and every reached fn with slice/array
/// indexing yields one aggregated `index-reachable` finding.  The
/// finding message carries a sample call path from an entry point.
///
/// Seeds are filtered per line before aggregation: a justified
/// `allow(panic-reachable)` — or srclint's own `allow(hot-path-panic)`,
/// which asserts the same "this cannot fire" invariant — on the seed
/// line (or the line above) excludes that seed; likewise a justified
/// `allow(index-reachable)` excludes an indexing site.  `comments`
/// maps file path → per-line comment text.
pub fn check_panic_reachability(
    g: &Graph,
    comments: &BTreeMap<String, Vec<String>>,
) -> Vec<Finding> {
    let roots: Vec<FnId> = g
        .entry_points(ENTRY_POINTS)
        .into_iter()
        .filter(|&id| !is_tooling(&g.fns[id].file))
        .collect();
    let reach = g.reach_forward(&roots, &|f| is_tooling(&f.file));
    let empty: Vec<String> = Vec::new();
    let mut out = Vec::new();
    for (&id, path) in &reach {
        let f = &g.fns[id];
        if is_tooling(&f.file) {
            continue;
        }
        let cs = comments.get(&f.file).unwrap_or(&empty);
        let seed_allowed = |line: usize, rules: &[&str]| {
            let li = line.saturating_sub(1);
            li < cs.len()
                && rules.iter().any(|&r| allow_at(cs, li, r) == Some(true))
        };
        let via = if path.len() > 1 {
            format!(" (via {})", g.path_label(path))
        } else {
            " (hot-path entry point)".to_string()
        };
        let mut seeds: Vec<usize> = Vec::new();
        for m in &f.body.methods {
            if PANIC_METHODS.contains(&m.name.as_str()) {
                // `self.expect(…)` resolving to a same-file impl fn is a
                // call to an in-repo helper (config/json.rs's parser has
                // one), not Option/Result::expect — the callee is already
                // an edge in the graph and is analyzed in its own right.
                let own_method = m.base == "self"
                    && g.named(&m.name).iter().any(|&c| {
                        let cf = &g.fns[c];
                        cf.file == f.file && cf.owner.is_some() && !cf.in_test
                    });
                if !own_method {
                    seeds.push(m.line);
                }
            }
        }
        for mc in &f.body.macros {
            if PANIC_MACROS.contains(&mc.name.as_str()) {
                seeds.push(mc.line);
            }
        }
        seeds.sort_unstable();
        seeds.dedup();
        seeds.retain(|&l| !seed_allowed(l, &[RULE_PANIC, "hot-path-panic"]));
        if let Some(&first) = seeds.first() {
            out.push(Finding {
                file: f.file.clone(),
                line: first,
                rule: RULE_PANIC,
                msg: format!(
                    "{} may panic at {} site(s) (lines {}){}",
                    f.label(),
                    seeds.len(),
                    fmt_lines(&seeds),
                    via
                ),
            });
        }
        let mut idx: Vec<usize> = f.body.indexes.clone();
        idx.sort_unstable();
        idx.dedup();
        idx.retain(|&l| !seed_allowed(l, &[RULE_INDEX]));
        if let Some(&first) = idx.first() {
            out.push(Finding {
                file: f.file.clone(),
                line: first,
                rule: RULE_INDEX,
                msg: format!(
                    "{} has {} slice/array indexing site(s) reachable from a hot path \
                     (lines {}){}",
                    f.label(),
                    idx.len(),
                    fmt_lines(&idx),
                    via
                ),
            });
        }
    }
    out
}

fn fmt_lines(lines: &[usize]) -> String {
    const MAX: usize = 6;
    let mut s: Vec<String> = lines.iter().take(MAX).map(|l| l.to_string()).collect();
    if lines.len() > MAX {
        s.push("…".to_string());
    }
    s.join(", ")
}

// ---------------------------------------------------------------------------
// Analysis 2: determinism dataflow
// ---------------------------------------------------------------------------

/// Nondeterminism sources and discarded results, crate-wide (non-test
/// fns), plus clock/thread-id calls restricted to fns that can reach a
/// result-sink construction.
pub fn check_determinism(g: &Graph) -> Vec<Finding> {
    let mut out = Vec::new();

    // Fns that can reach a result-sink constructor (for clock rule).
    let sinks: Vec<FnId> = g
        .fns
        .iter()
        .enumerate()
        .filter(|(_, f)| {
            !f.in_test
                && f.body
                    .struct_lits
                    .iter()
                    .any(|s| RESULT_SINKS.contains(&s.name.as_str()))
        })
        .map(|(id, _)| id)
        .collect();
    let feeds_results = g.reach_reverse(&sinks);

    for (id, f) in g.fns.iter().enumerate() {
        if f.in_test || is_tooling(&f.file) {
            continue;
        }
        let hashy = |name: &str| f.body.hash_locals.iter().any(|h| h.as_str() == name);

        // HashMap/HashSet iteration: `for … in <hash local>` or an
        // iteration method on a hash-typed receiver.
        for l in &f.body.loops {
            if l.idents.iter().any(|i| hashy(i)) {
                out.push(Finding {
                    file: f.file.clone(),
                    line: l.line,
                    rule: RULE_HASH_ITER,
                    msg: format!(
                        "{} iterates a HashMap/HashSet (`for … in {}`): iteration order \
                         is nondeterministic; use BTreeMap/BTreeSet or sort first",
                        f.label(),
                        l.text
                    ),
                });
            }
        }
        for m in &f.body.methods {
            let iterish = matches!(
                m.name.as_str(),
                "iter" | "iter_mut" | "into_iter" | "keys" | "values" | "values_mut" | "drain"
            );
            let base_head = m.base.split('.').next().unwrap_or("");
            if iterish && (hashy(&m.base) || hashy(base_head)) {
                out.push(Finding {
                    file: f.file.clone(),
                    line: m.line,
                    rule: RULE_HASH_ITER,
                    msg: format!(
                        "{} calls .{}() on hash-typed `{}`: iteration order is \
                         nondeterministic; use BTreeMap/BTreeSet or sort first",
                        f.label(),
                        m.name,
                        m.base
                    ),
                });
            }
        }

        // Unordered float reductions: `.sum::<f64>()` (or f32) over a
        // hash-typed receiver chain — float addition is not
        // associative, so unordered accumulation drifts bit-for-bit.
        for m in &f.body.methods {
            let reduces = m.name == "sum" || m.name == "product";
            let floaty = m.turbofish.contains("f64") || m.turbofish.contains("f32");
            let base_head = m.base.split('.').next().unwrap_or("");
            if reduces && floaty && (hashy(&m.base) || hashy(base_head)) {
                out.push(Finding {
                    file: f.file.clone(),
                    line: m.line,
                    rule: RULE_FLOAT_SUM,
                    msg: format!(
                        "{} reduces floats over hash-ordered `{}` with .{}::<{}>(): \
                         accumulation order varies run to run",
                        f.label(),
                        m.base,
                        m.name,
                        m.turbofish
                    ),
                });
            }
        }

        // Raw thread spawns outside the sanctioned modules.
        let spawn_ok = SPAWN_ALLOWED.iter().any(|p| f.file.starts_with(p));
        if !spawn_ok {
            for c in &f.body.calls {
                if c.path == "thread::spawn" || c.path.ends_with("::thread::spawn") {
                    out.push(Finding {
                        file: f.file.clone(),
                        line: c.line,
                        rule: RULE_SPAWN,
                        msg: format!(
                            "{} spawns a raw thread outside {:?}: completion order is \
                             unobservable to the deterministic engine",
                            f.label(),
                            SPAWN_ALLOWED
                        ),
                    });
                }
            }
        }

        // Discarded results: `let _ = call(…)` silently drops errors.
        for d in &f.body.discards {
            if d.has_call {
                out.push(Finding {
                    file: f.file.clone(),
                    line: d.line,
                    rule: RULE_DISCARD,
                    msg: format!(
                        "{} discards a call result with `let _ = …`: handle the \
                         Result or document why it is ignorable",
                        f.label()
                    ),
                });
            }
        }

        // Wall-clock / thread-id flowing toward result structs.
        if feeds_results.contains(&id) {
            for c in &f.body.calls {
                let clocky = c.path.ends_with("Instant::now")
                    || c.path.ends_with("SystemTime::now")
                    || c.path.ends_with("thread::current");
                if clocky {
                    out.push(Finding {
                        file: f.file.clone(),
                        line: c.line,
                        rule: RULE_CLOCK,
                        msg: format!(
                            "{} calls {} and can reach a {:?} construction: wall-clock \
                             or thread identity must not flow into results",
                            f.label(),
                            c.path,
                            RESULT_SINKS
                        ),
                    });
                }
            }
        }
    }

    // Narrow integer casts, crate-wide including tooling (silent
    // truncation corrupts metrics and indices alike).
    for f in g.fns.iter() {
        if f.in_test {
            continue;
        }
        for c in &f.body.casts {
            if NARROW_INTS.contains(&c.to.as_str()) {
                out.push(Finding {
                    file: f.file.clone(),
                    line: c.line,
                    rule: RULE_TRUNCATION,
                    msg: format!(
                        "{} casts with `as {}`: silently truncates; use try_from or \
                         justify the range",
                        f.label(),
                        c.to
                    ),
                });
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Analysis 3: metric-plumbing consistency
// ---------------------------------------------------------------------------

/// Where a `SimResult` metric must surface.
pub enum Plumb {
    /// A field or method with this name must exist on one of the
    /// report-side types (`DynamicReport`, `DynCellStats`, `CellStats`).
    Report(&'static str),
    /// A string literal containing this key must appear in the CLI
    /// sweep/JSON emitters (`cli/`).
    Emit(&'static str),
    /// Deliberately not plumbed; the rationale is part of the table.
    Exempt(&'static str),
}

/// The plumbing registry: every `pub` field of `SimResult` must have a
/// row here, and every row must still name a real field.  Adding a
/// metric to `SimResult` without registering how it surfaces (or why
/// it doesn't) is a CI failure — that is the point.
pub const PLUMBING: &[(&str, &[Plumb])] = &[
    ("throughput", &[Plumb::Report("mean_x"), Plumb::Emit("mean_x")]),
    ("mean_response", &[Plumb::Report("mean_response")]),
    ("mean_energy", &[Plumb::Report("mean_energy"), Plumb::Emit("mean_energy")]),
    ("edp", &[Plumb::Report("mean_edp"), Plumb::Emit("mean_edp")]),
    (
        "little_product",
        &[Plumb::Exempt(
            "Little's-law residual X·E[T]≈N; diagnostic invariant shown in the \
             scenario table and asserted in tests, not a sweep metric",
        )],
    ),
    (
        "n_programs",
        &[Plumb::Exempt("workload-size echo of an input parameter, not a measurement")],
    ),
    (
        "completed",
        &[Plumb::Exempt(
            "absolute completion count; throughput (completions per unit time) is \
             the normalized, reported form",
        )],
    ),
    (
        "tasks_redispatched",
        &[Plumb::Report("tasks_redispatched"), Plumb::Report("mean_redispatched")],
    ),
    ("downtime_frac", &[Plumb::Report("mean_downtime_frac")]),
    (
        "completions_by_cell",
        &[Plumb::Report("mean_class_x")],
    ),
    ("deadline_misses", &[Plumb::Report("mean_miss_rate")]),
    (
        "p99_by_class",
        &[Plumb::Exempt(
            "per-class p99 response tail; surfaced through the dynamic phase \
             records (DynamicReport.phases) rather than aggregated cells",
        )],
    ),
];

/// Inputs to the plumbing check, pre-extracted by the driver.
pub struct PlumbingInputs {
    /// `SimResult`'s field declarations and their source location.
    pub sim_result_fields: Vec<FieldDecl>,
    pub sim_result_file: String,
    pub sim_result_line: usize,
    /// Field and method names found on the report-side types.
    pub report_names: Vec<String>,
    /// String literals in `cli/` files.
    pub cli_strings: Vec<String>,
}

/// Collect [`PlumbingInputs`] from parsed files.
pub fn plumbing_inputs(files: &[(String, Vec<Item>)], cli_strings: Vec<String>) -> Option<PlumbingInputs> {
    let mut inp = PlumbingInputs {
        sim_result_fields: Vec::new(),
        sim_result_file: String::new(),
        sim_result_line: 0,
        report_names: Vec::new(),
        cli_strings,
    };
    let report_types = ["DynamicReport", "DynCellStats", "CellStats"];
    fn walk(items: &[Item], f: &mut dyn FnMut(&Item)) {
        for it in items {
            f(it);
            walk(&it.children, f);
        }
    }
    for (path, items) in files {
        walk(items, &mut |it| {
            if it.kind == ItemKind::Struct && it.name == "SimResult" && path.ends_with("sim/metrics.rs")
            {
                inp.sim_result_fields = it.fields.clone();
                inp.sim_result_file = path.clone();
                inp.sim_result_line = it.line;
            }
            if it.kind == ItemKind::Struct && report_types.contains(&it.name.as_str()) {
                for fd in &it.fields {
                    inp.report_names.push(fd.name.clone());
                }
            }
            if it.kind == ItemKind::Impl && report_types.contains(&it.name.as_str()) {
                for c in &it.children {
                    if c.kind == ItemKind::Fn {
                        inp.report_names.push(c.name.clone());
                    }
                }
            }
        });
    }
    if inp.sim_result_fields.is_empty() {
        return None;
    }
    Some(inp)
}

/// Every pub `SimResult` field registered; every registered needle
/// still resolvable.
pub fn check_plumbing(inp: &PlumbingInputs) -> Vec<Finding> {
    let mut out = Vec::new();
    let table: BTreeMap<&str, &[Plumb]> = PLUMBING.iter().map(|(k, v)| (*k, *v)).collect();
    for fd in &inp.sim_result_fields {
        if !fd.public {
            continue;
        }
        match table.get(fd.name.as_str()) {
            None => out.push(Finding {
                file: inp.sim_result_file.clone(),
                line: fd.line,
                rule: RULE_PLUMBING,
                msg: format!(
                    "SimResult field `{}` is not registered in the plumbing table \
                     (analysis/checks.rs PLUMBING): add a Report/Emit/Exempt row \
                     saying how it surfaces",
                    fd.name
                ),
            }),
            Some(plumbs) => {
                for p in *plumbs {
                    match p {
                        Plumb::Report(needle) => {
                            if !inp.report_names.iter().any(|n| n.as_str() == *needle) {
                                out.push(Finding {
                                    file: inp.sim_result_file.clone(),
                                    line: fd.line,
                                    rule: RULE_PLUMBING,
                                    msg: format!(
                                        "SimResult field `{}` claims report counterpart \
                                         `{}`, but no such field/method exists on \
                                         DynamicReport/DynCellStats/CellStats",
                                        fd.name, needle
                                    ),
                                });
                            }
                        }
                        Plumb::Emit(needle) => {
                            if !inp.cli_strings.iter().any(|s| s.contains(*needle)) {
                                out.push(Finding {
                                    file: inp.sim_result_file.clone(),
                                    line: fd.line,
                                    rule: RULE_PLUMBING,
                                    msg: format!(
                                        "SimResult field `{}` claims sweep-JSON key \
                                         `{}`, but no cli/ string literal mentions it",
                                        fd.name, needle
                                    ),
                                });
                            }
                        }
                        Plumb::Exempt(_) => {}
                    }
                }
            }
        }
    }
    // Stale rows: registered fields that no longer exist.
    for (name, _) in PLUMBING {
        if !inp.sim_result_fields.iter().any(|fd| fd.name == *name) {
            out.push(Finding {
                file: inp.sim_result_file.clone(),
                line: inp.sim_result_line,
                rule: RULE_PLUMBING,
                msg: format!(
                    "plumbing table registers `{}` but SimResult has no such field: \
                     remove the stale row",
                    name
                ),
            });
        }
    }
    out
}
