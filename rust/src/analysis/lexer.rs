//! Shared source lexer for the in-repo analyzers.
//!
//! One token-level pass over raw Rust source feeds two consumers:
//!
//! * [`crate::lint`] (srclint) keeps its original line-oriented view:
//!   [`mask`] rebuilds the per-line masked code / comment split it has
//!   always used, now derived from the token stream instead of a
//!   private character scanner.
//! * [`crate::analysis`] (detlint) consumes the [`Token`] stream
//!   directly: identifiers, lifetimes, numbers, string *contents*
//!   (needed by the metric-plumbing check, which looks for JSON keys),
//!   and punctuation with exact line/column spans.
//!
//! `<` and `>` are always emitted as single-character punctuation —
//! `Vec<Arc<Mutex<T>>>` lexes as three separate `>` tokens, so the
//! parser never has to split a `>>` shift token inside nested
//! generics.  Multi-character operators that the parser does rely on
//! (`::`, `->`, `=>`, `..`, `..=`, `...`) stay glued.
//!
//! The suppression grammar is parsed here too ([`allow_at`],
//! [`file_allow`]): both srclint and detlint accept
//! `// srclint: allow(<rule>) — <justification>` on the finding line
//! or the line above, and detlint additionally accepts a file-scoped
//! `// srclint: allow-file(<rule>) — <justification>` on any line of
//! the file.  A justification of fewer than 8 alphanumeric characters
//! does not count.

/// One lexical token.
#[derive(Clone, Debug, PartialEq)]
pub enum Tok {
    /// Identifier or keyword (`fn`, `foo`, `usize`, …).
    Ident(String),
    /// Lifetime (`'a`, `'static`), with the leading quote.
    Lifetime(String),
    /// Numeric literal, suffix included (`1_000u32`, `0x1f`, `2.5e-3`).
    Num(String),
    /// String literal *contents* (escapes unprocessed, quotes and any
    /// raw-string hashes stripped).  Covers `"…"`, `r"…"`, `r#"…"#`
    /// and their `b`-prefixed forms.
    Str(String),
    /// Character or byte literal (contents irrelevant to any analysis).
    Char,
    /// Punctuation; multi-character only for `::`, `->`, `=>`, `..`,
    /// `..=`, `...`.
    Punct(String),
}

impl Tok {
    /// Identifier text, if this token is one.
    pub fn ident(&self) -> Option<&str> {
        match self {
            Tok::Ident(s) => Some(s),
            _ => None,
        }
    }

    /// True if this token is the punctuation `p`.
    pub fn is_punct(&self, p: &str) -> bool {
        matches!(self, Tok::Punct(s) if s == p)
    }

    /// True if this token is the identifier/keyword `k`.
    pub fn is_ident(&self, k: &str) -> bool {
        matches!(self, Tok::Ident(s) if s == k)
    }
}

/// A token plus its source location (1-based line, 0-based char column).
#[derive(Clone, Debug)]
pub struct Token {
    pub tok: Tok,
    pub line: usize,
    pub col: usize,
}

/// Full lexer output: the token stream plus per-line comment text
/// (comment characters at their original columns, everything else
/// blanked — the view the suppression parser works on).
#[derive(Clone, Debug)]
pub struct Lexed {
    pub tokens: Vec<Token>,
    pub comments: Vec<String>,
    /// Char length of each source line (for masked-view reconstruction).
    line_lens: Vec<usize>,
}

/// Source split into a masked code view (comments, string and char
/// literal *contents* blanked to spaces, line structure preserved) and
/// the comment text per line — srclint's working representation.
pub struct Masked {
    pub code: Vec<String>,
    pub comments: Vec<String>,
}

fn is_ident_start(c: char) -> bool {
    c.is_ascii_alphabetic() || c == '_'
}

fn is_ident_char(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

/// Character-level cursor with line/column tracking and per-line
/// comment accumulation.
struct Scanner {
    cs: Vec<char>,
    i: usize,
    line: usize, // 1-based
    col: usize,  // 0-based, chars
    comments: Vec<String>,
    line_lens: Vec<usize>,
}

impl Scanner {
    fn new(src: &str) -> Self {
        let line_lens: Vec<usize> = src.split('\n').map(|l| l.chars().count()).collect();
        let n_lines = line_lens.len();
        Scanner {
            cs: src.chars().collect(),
            i: 0,
            line: 1,
            col: 0,
            comments: vec![String::new(); n_lines],
            line_lens,
        }
    }

    fn peek(&self, k: usize) -> Option<char> {
        self.cs.get(self.i + k).copied()
    }

    fn cur(&self) -> Option<char> {
        self.peek(0)
    }

    /// Advance one char, maintaining line/col.
    fn bump(&mut self) -> Option<char> {
        let c = self.cur()?;
        self.i += 1;
        if c == '\n' {
            self.line += 1;
            self.col = 0;
        } else {
            self.col += 1;
        }
        Some(c)
    }

    /// Record `c` (just consumed) as comment text at the position it
    /// occupied.
    fn note_comment(&mut self, c: char, line: usize, col: usize) {
        if c == '\n' {
            return;
        }
        let buf = &mut self.comments[line - 1];
        while buf.chars().count() < col {
            buf.push(' ');
        }
        buf.push(c);
    }
}

/// Lex `src` into tokens + comment lines.  Never fails: unrecognized
/// characters become single-char punctuation.
pub fn lex(src: &str) -> Lexed {
    let mut s = Scanner::new(src);
    let mut tokens: Vec<Token> = Vec::new();
    while let Some(c) = s.cur() {
        let (line, col) = (s.line, s.col);
        // Line comment (incl. doc comments).
        if c == '/' && s.peek(1) == Some('/') {
            while let Some(ch) = s.cur() {
                if ch == '\n' {
                    break;
                }
                let (l, co) = (s.line, s.col);
                s.bump();
                s.note_comment(ch, l, co);
            }
            continue;
        }
        // Block comment, nested.
        if c == '/' && s.peek(1) == Some('*') {
            let mut depth = 0usize;
            loop {
                match (s.cur(), s.peek(1)) {
                    (Some('/'), Some('*')) => {
                        depth += 1;
                        for _ in 0..2 {
                            let (l, co, ch) = (s.line, s.col, s.cur().expect("peeked"));
                            s.bump();
                            s.note_comment(ch, l, co);
                        }
                    }
                    (Some('*'), Some('/')) => {
                        depth -= 1;
                        for _ in 0..2 {
                            let (l, co, ch) = (s.line, s.col, s.cur().expect("peeked"));
                            s.bump();
                            s.note_comment(ch, l, co);
                        }
                        if depth == 0 {
                            break;
                        }
                    }
                    (Some(ch), _) => {
                        let (l, co) = (s.line, s.col);
                        s.bump();
                        s.note_comment(ch, l, co);
                    }
                    (None, _) => break,
                }
            }
            continue;
        }
        // Raw strings r"…" / r#"…"# / br#"…"# and byte strings b"…".
        if c == 'r' || c == 'b' {
            let prev_ident = s.i > 0 && is_ident_char(s.cs[s.i - 1]);
            if !prev_ident {
                if let Some(tok) = try_string_prefix(&mut s) {
                    tokens.push(Token { tok, line, col });
                    continue;
                }
            }
        }
        // Ordinary string.
        if c == '"' {
            s.bump();
            let content = scan_string_body(&mut s);
            tokens.push(Token { tok: Tok::Str(content), line, col });
            continue;
        }
        // Char literal vs lifetime: only 'x' or '\…' are literals.
        if c == '\'' {
            let is_escape = s.peek(1) == Some('\\');
            let is_short = s.peek(2) == Some('\'') && s.peek(1) != Some('\\');
            if is_escape || is_short {
                s.bump(); // opening quote
                while let Some(ch) = s.cur() {
                    if ch == '\\' {
                        s.bump();
                        s.bump();
                        continue;
                    }
                    s.bump();
                    if ch == '\'' {
                        break;
                    }
                }
                tokens.push(Token { tok: Tok::Char, line, col });
                continue;
            }
            // Lifetime: quote + ident chars.
            let mut text = String::from('\'');
            s.bump();
            while let Some(ch) = s.cur() {
                if is_ident_char(ch) {
                    text.push(ch);
                    s.bump();
                } else {
                    break;
                }
            }
            tokens.push(Token { tok: Tok::Lifetime(text), line, col });
            continue;
        }
        // Identifier / keyword.
        if is_ident_start(c) {
            let mut text = String::new();
            while let Some(ch) = s.cur() {
                if is_ident_char(ch) {
                    text.push(ch);
                    s.bump();
                } else {
                    break;
                }
            }
            tokens.push(Token { tok: Tok::Ident(text), line, col });
            continue;
        }
        // Number.
        if c.is_ascii_digit() {
            let text = scan_number(&mut s);
            tokens.push(Token { tok: Tok::Num(text), line, col });
            continue;
        }
        // Whitespace.
        if c.is_whitespace() {
            s.bump();
            continue;
        }
        // Punctuation: glue only the operators the parser needs.
        let tok = scan_punct(&mut s);
        tokens.push(Token { tok, line, col });
    }
    Lexed { tokens, comments: s.comments, line_lens: s.line_lens }
}

/// Try to consume a `r"…"`/`r#"…"#`/`br#"…"#`/`b"…"`/`b'x'` literal at
/// the cursor (which sits on `r` or `b`).  Returns the token, or None
/// if this is a plain identifier.
fn try_string_prefix(s: &mut Scanner) -> Option<Tok> {
    let c = s.cur().expect("caller checked");
    let mut j = 1usize; // offset past the prefix letter(s)
    if c == 'b' {
        match s.peek(1) {
            Some('\'') => {
                // Byte literal b'x'.
                s.bump(); // b
                s.bump(); // '
                while let Some(ch) = s.cur() {
                    if ch == '\\' {
                        s.bump();
                        s.bump();
                        continue;
                    }
                    s.bump();
                    if ch == '\'' {
                        break;
                    }
                }
                return Some(Tok::Char);
            }
            Some('"') => {
                s.bump(); // b
                s.bump(); // "
                return Some(Tok::Str(scan_string_body(s)));
            }
            Some('r') => j = 2,
            _ => return None,
        }
    }
    // Raw string: r or br, then #*, then ".
    let mut hashes = 0usize;
    while s.peek(j + hashes) == Some('#') {
        hashes += 1;
    }
    if s.peek(j + hashes) != Some('"') {
        return None;
    }
    for _ in 0..j + hashes + 1 {
        s.bump();
    }
    let mut content = String::new();
    'raw: while let Some(ch) = s.cur() {
        if ch == '"' {
            let mut k = 0usize;
            while k < hashes && s.peek(1 + k) == Some('#') {
                k += 1;
            }
            if k == hashes {
                for _ in 0..=hashes {
                    s.bump();
                }
                break 'raw;
            }
        }
        content.push(ch);
        s.bump();
    }
    Some(Tok::Str(content))
}

/// Scan an ordinary (cooked) string body after the opening quote.
fn scan_string_body(s: &mut Scanner) -> String {
    let mut content = String::new();
    while let Some(ch) = s.cur() {
        if ch == '\\' {
            content.push(ch);
            s.bump();
            if let Some(esc) = s.cur() {
                content.push(esc);
                s.bump();
            }
            continue;
        }
        if ch == '"' {
            s.bump();
            break;
        }
        content.push(ch);
        s.bump();
    }
    content
}

/// Scan a numeric literal (cursor on the first digit).
fn scan_number(s: &mut Scanner) -> String {
    let mut text = String::new();
    let radix_prefix = s.cur() == Some('0')
        && matches!(s.peek(1), Some('x') | Some('o') | Some('b') | Some('X') | Some('O') | Some('B'));
    if radix_prefix {
        text.push(s.bump().expect("digit"));
        text.push(s.bump().expect("radix"));
        while let Some(ch) = s.cur() {
            if ch.is_ascii_alphanumeric() || ch == '_' {
                text.push(ch);
                s.bump();
            } else {
                break;
            }
        }
        return text;
    }
    while let Some(ch) = s.cur() {
        if ch.is_ascii_digit() || ch == '_' {
            text.push(ch);
            s.bump();
        } else {
            break;
        }
    }
    // Fractional part — but never eat `..` (range) or `.method()`.
    if s.cur() == Some('.') && s.peek(1).map(|c| c.is_ascii_digit()).unwrap_or(false) {
        text.push('.');
        s.bump();
        while let Some(ch) = s.cur() {
            if ch.is_ascii_digit() || ch == '_' {
                text.push(ch);
                s.bump();
            } else {
                break;
            }
        }
    }
    // Exponent.
    if matches!(s.cur(), Some('e') | Some('E')) {
        let sign = matches!(s.peek(1), Some('+') | Some('-'));
        let digit_at = if sign { 2 } else { 1 };
        if s.peek(digit_at).map(|c| c.is_ascii_digit()).unwrap_or(false) {
            text.push(s.bump().expect("e"));
            if sign {
                text.push(s.bump().expect("sign"));
            }
            while let Some(ch) = s.cur() {
                if ch.is_ascii_digit() || ch == '_' {
                    text.push(ch);
                    s.bump();
                } else {
                    break;
                }
            }
        }
    }
    // Type suffix (u32, f64, usize, …).
    while let Some(ch) = s.cur() {
        if is_ident_char(ch) {
            text.push(ch);
            s.bump();
        } else {
            break;
        }
    }
    text
}

/// Scan one punctuation token, gluing only parser-relevant operators.
fn scan_punct(s: &mut Scanner) -> Tok {
    let c = s.bump().expect("caller checked");
    let next = s.cur();
    let glued: Option<&str> = match (c, next) {
        (':', Some(':')) => Some("::"),
        ('-', Some('>')) => Some("->"),
        ('=', Some('>')) => Some("=>"),
        ('.', Some('.')) => {
            s.bump();
            return match s.cur() {
                Some('=') => {
                    s.bump();
                    Tok::Punct("..=".to_string())
                }
                Some('.') => {
                    s.bump();
                    Tok::Punct("...".to_string())
                }
                _ => Tok::Punct("..".to_string()),
            };
        }
        _ => None,
    };
    if let Some(op) = glued {
        s.bump();
        return Tok::Punct(op.to_string());
    }
    Tok::Punct(c.to_string())
}

// ---------------------------------------------------------------------------
// srclint's masked view, reconstructed from the token stream
// ---------------------------------------------------------------------------

/// Rebuild srclint's per-line masked code view from a lex: code tokens
/// at their original columns, everything else (comments, string/char
/// contents) blanked to spaces.
pub fn mask(src: &str) -> Masked {
    let lexed = lex(src);
    let mut code: Vec<Vec<char>> =
        lexed.line_lens.iter().map(|&n| vec![' '; n]).collect();
    for t in &lexed.tokens {
        let text: &str = match &t.tok {
            Tok::Ident(s) => s,
            Tok::Lifetime(s) => s,
            Tok::Num(s) => s,
            Tok::Punct(s) => s,
            // String/char contents stay masked.
            Tok::Str(_) | Tok::Char => continue,
        };
        let row = &mut code[t.line - 1];
        for (k, ch) in text.chars().enumerate() {
            if let Some(slot) = row.get_mut(t.col + k) {
                *slot = ch;
            }
        }
    }
    let mut comments = lexed.comments;
    // Pad comment lines to the source line length so column-aligned
    // consumers see a stable shape.
    for (li, buf) in comments.iter_mut().enumerate() {
        let want = lexed.line_lens[li];
        while buf.chars().count() < want {
            buf.push(' ');
        }
    }
    Masked { code: code.into_iter().map(|v| v.into_iter().collect()).collect(), comments }
}

// ---------------------------------------------------------------------------
// Suppression parsing (shared grammar)
// ---------------------------------------------------------------------------

/// Minimum alphanumeric length for a justification to count.
const MIN_JUSTIFICATION: usize = 8;

fn justified(after: &str) -> bool {
    let reason: String = after.chars().filter(|c| c.is_alphanumeric() || *c == ' ').collect();
    reason.trim().len() >= MIN_JUSTIFICATION
}

/// Returns `Some(justified)` if line `li` (0-based) or the line above
/// carries `srclint: allow(<rule>)`; `justified` is false when the
/// allow has no reason text after the closing paren.
pub fn allow_at(comments: &[String], li: usize, rule: &str) -> Option<bool> {
    let needle = format!("srclint: allow({rule})");
    for cand in [Some(li), li.checked_sub(1)].into_iter().flatten() {
        if let Some(pos) = comments[cand].find(&needle) {
            return Some(justified(&comments[cand][pos + needle.len()..]));
        }
    }
    None
}

/// Returns `Some(justified)` if any comment line in the file carries a
/// file-scoped `srclint: allow-file(<rule>)` — detlint's coarse-grained
/// suppression for rules (like `index-reachable`) where a module-wide
/// invariant covers every site.
pub fn file_allow(comments: &[String], rule: &str) -> Option<bool> {
    let needle = format!("srclint: allow-file({rule})");
    for line in comments {
        if let Some(pos) = line.find(&needle) {
            return Some(justified(&line[pos + needle.len()..]));
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(src: &str) -> Vec<String> {
        lex(src)
            .tokens
            .iter()
            .filter_map(|t| t.tok.ident().map(str::to_string))
            .collect()
    }

    #[test]
    fn splits_nested_generic_closers() {
        let toks = lex("Vec<Arc<Mutex<T>>>").tokens;
        let closers = toks.iter().filter(|t| t.tok.is_punct(">")).count();
        assert_eq!(closers, 3, "{toks:?}");
        // And `>>=`-style operators degrade to single '>' too.
        let toks = lex("a >>= b").tokens;
        assert_eq!(toks.iter().filter(|t| t.tok.is_punct(">")).count(), 2);
    }

    #[test]
    fn raw_strings_keep_contents() {
        let toks = lex(r####"let s = r#"panic!("x") "quoted""#;"####).tokens;
        let strs: Vec<&str> = toks
            .iter()
            .filter_map(|t| match &t.tok {
                Tok::Str(s) => Some(s.as_str()),
                _ => None,
            })
            .collect();
        assert_eq!(strs, [r#"panic!("x") "quoted""#]);
    }

    #[test]
    fn byte_and_cooked_strings() {
        let toks = lex(r#"let a = b"bytes"; let c = "say \"hi\"";"#).tokens;
        let strs: Vec<&str> = toks
            .iter()
            .filter_map(|t| match &t.tok {
                Tok::Str(s) => Some(s.as_str()),
                _ => None,
            })
            .collect();
        assert_eq!(strs, ["bytes", r#"say \"hi\""#]);
    }

    #[test]
    fn lifetimes_vs_char_literals() {
        let toks = lex("fn f<'a>(x: &'a str) { let c = '\\''; let d = 'y'; }").tokens;
        let lifetimes: Vec<&str> = toks
            .iter()
            .filter_map(|t| match &t.tok {
                Tok::Lifetime(s) => Some(s.as_str()),
                _ => None,
            })
            .collect();
        assert_eq!(lifetimes, ["'a", "'a"]);
        assert_eq!(toks.iter().filter(|t| matches!(t.tok, Tok::Char)).count(), 2);
    }

    #[test]
    fn numbers_with_suffixes_and_exponents() {
        let src = "let a = 1_000u32 + 0x1f; let b = 2.5e-3f64; let r = 0..n; let t = x.0;";
        let nums: Vec<String> = lex(src)
            .tokens
            .iter()
            .filter_map(|t| match &t.tok {
                Tok::Num(s) => Some(s.clone()),
                _ => None,
            })
            .collect();
        assert_eq!(nums, ["1_000u32", "0x1f", "2.5e-3f64", "0", "0"]);
        assert!(lex(src).tokens.iter().any(|t| t.tok.is_punct("..")));
    }

    #[test]
    fn comments_collected_per_line() {
        let src = "let x = 1; // trailing note\n/* block\nspans lines */ let y = 2;\n";
        let lx = lex(src);
        assert!(lx.comments[0].contains("trailing note"));
        assert!(lx.comments[1].contains("block"));
        assert!(lx.comments[2].contains("spans lines"));
        assert!(idents(src).contains(&"y".to_string()));
    }

    #[test]
    fn spans_are_line_and_col_exact() {
        let src = "fn foo() {\n    bar();\n}\n";
        let lx = lex(src);
        let bar = lx
            .tokens
            .iter()
            .find(|t| t.tok.is_ident("bar"))
            .expect("bar token");
        assert_eq!((bar.line, bar.col), (2, 4));
    }

    #[test]
    fn mask_matches_legacy_shape() {
        let src = "let s = \"std::sync::Mutex\"; // note\nlet t = r#\"panic!(\"x\")\"#;\n";
        let m = mask(src);
        assert!(!m.code[0].contains("std::sync"));
        assert!(m.code[0].contains("let s ="));
        assert!(!m.code[1].contains("panic!"));
        assert!(m.comments[0].contains("note"));
    }

    #[test]
    fn file_allow_requires_justification() {
        let ok = ["// srclint: allow-file(index-reachable) — dense kernel, dims checked".to_string()];
        assert_eq!(file_allow(&ok, "index-reachable"), Some(true));
        let bare = ["// srclint: allow-file(index-reachable)".to_string()];
        assert_eq!(file_allow(&bare, "index-reachable"), Some(false));
        assert_eq!(file_allow(&bare, "other-rule"), None);
    }
}
