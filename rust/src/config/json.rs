//! Minimal strict JSON: recursive-descent parser and writer.
//!
//! Supports the full JSON grammar (RFC 8259) minus surrogate-pair unicode
//! escapes (rejected explicitly).  Numbers parse as f64 — ample for the
//! manifest and experiment configs this crate reads.

// srclint: allow-file(index-reachable) — byte indices are cursor positions already bounds-checked by the scanner

use crate::error::{Error, Result};

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// Any number (f64).
    Num(f64),
    /// String.
    Str(String),
    /// Array.
    Arr(Vec<Json>),
    /// Object (insertion-ordered).
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Parse a complete JSON document (trailing whitespace allowed only).
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Required object field.
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key)
            .ok_or_else(|| Error::Parse(format!("missing field '{key}'")))
    }

    /// As f64.
    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => Err(Error::Parse(format!("expected number, got {self:?}"))),
        }
    }

    /// As u64 (must be a non-negative integer value).
    pub fn as_u64(&self) -> Result<u64> {
        let f = self.as_f64()?;
        if f < 0.0 || f.fract() != 0.0 || f > u64::MAX as f64 {
            return Err(Error::Parse(format!("expected unsigned integer, got {f}")));
        }
        Ok(f as u64)
    }

    /// As str.
    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => Err(Error::Parse(format!("expected string, got {self:?}"))),
        }
    }

    /// As array.
    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            _ => Err(Error::Parse(format!("expected array, got {self:?}"))),
        }
    }

    /// As object fields.
    pub fn as_obj(&self) -> Result<&[(String, Json)]> {
        match self {
            Json::Obj(o) => Ok(o),
            _ => Err(Error::Parse(format!("expected object, got {self:?}"))),
        }
    }

    /// Serialize compactly.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            // srclint: allow(as-truncation) — char to u32 is value-preserving by definition
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> Error {
        Error::Parse(format!("json: {msg} at byte {}", self.i))
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek().ok_or_else(|| self.err("unexpected end"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.keyword("true", Json::Bool(true)),
            b'f' => self.keyword("false", Json::Bool(false)),
            b'n' => self.keyword("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(self.err(&format!("unexpected byte '{}'", c as char))),
        }
    }

    fn keyword(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .is_some_and(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])
            .map_err(|_| self.err("invalid utf8 in number"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err(&format!("bad number '{text}'")))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("unterminated string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("short \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            match char::from_u32(code) {
                                Some(ch) if !(0xD800..=0xDFFF).contains(&code) => out.push(ch),
                                _ => return Err(self.err("surrogate escapes unsupported")),
                            }
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                _ => {
                    // Re-consume as UTF-8: back up and take the char.
                    self.i -= 1;
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf8"))?;
                    // srclint: allow(panic-reachable) — the escape scanner only runs with bytes remaining, so a first char exists
                    let ch = rest.chars().next().unwrap();
                    // srclint: allow(as-truncation) — char to u32 is value-preserving by definition
                    if (ch as u32) < 0x20 {
                        return Err(self.err("control character in string"));
                    }
                    out.push(ch);
                    self.i += ch.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            fields.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(fields));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shaped_document() {
        let doc = r#"{
          "format": 1,
          "entries": {
            "nn2000": {
              "file": "nn2000.hlo.txt",
              "args": [{"shape": [32, 2048], "dtype": "float32"}],
              "out_arity": 2
            }
          }
        }"#;
        let j = Json::parse(doc).unwrap();
        assert_eq!(j.req("format").unwrap().as_u64().unwrap(), 1);
        let e = j.req("entries").unwrap().req("nn2000").unwrap();
        assert_eq!(e.req("file").unwrap().as_str().unwrap(), "nn2000.hlo.txt");
        let shape = e.req("args").unwrap().as_arr().unwrap()[0]
            .req("shape")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(shape[1].as_u64().unwrap(), 2048);
    }

    #[test]
    fn round_trips() {
        let cases = [
            r#"null"#,
            r#"true"#,
            r#"[1,2.5,-3,"x",{"a":[]},null]"#,
            r#"{"k":"v","n":{"deep":[false]}}"#,
        ];
        for c in cases {
            let j = Json::parse(c).unwrap();
            let s = j.to_string_compact();
            assert_eq!(Json::parse(&s).unwrap(), j, "{c}");
        }
    }

    #[test]
    fn string_escapes() {
        let j = Json::parse(r#""a\nb\t\"q\"A""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "a\nb\t\"q\"A");
        // Unicode passthrough.
        let j = Json::parse(r#""héllo θ""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "héllo θ");
    }

    #[test]
    fn rejects_malformed() {
        for bad in [
            "",
            "{",
            "[1,]",
            "{\"a\":}",
            "tru",
            "\"unterminated",
            "1 2",
            "{\"a\":1,}",
            "\"\\ud800\"", // surrogate escape unsupported
            "[0x1]",
        ] {
            assert!(Json::parse(bad).is_err(), "accepted: {bad}");
        }
    }

    #[test]
    fn numbers() {
        assert_eq!(Json::parse("-0.5e2").unwrap().as_f64().unwrap(), -50.0);
        assert_eq!(Json::parse("12").unwrap().as_u64().unwrap(), 12);
        assert!(Json::parse("1.5").unwrap().as_u64().is_err());
        assert!(Json::parse("-1").unwrap().as_u64().is_err());
    }
}
