//! Configuration substrate (no `serde` available offline).
//!
//! * [`json`] — a strict, dependency-free JSON parser + writer used for
//!   the artifact manifest (`artifacts/manifest.json`) and experiment
//!   config files.
//! * [`schema`] — typed experiment configuration (`ExperimentSpec`) with
//!   validation, consumed by the CLI launcher.

pub mod json;
pub mod schema;
