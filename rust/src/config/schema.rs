//! Typed experiment configuration consumed by the CLI launcher.
//!
//! JSON shape (see `examples/configs/` for shipped specs):
//!
//! ```json
//! {
//!   "mu": [[20, 15], [3, 8]],
//!   "populations": [10, 10],
//!   "policy": "cab",
//!   "distribution": "exp",
//!   "discipline": "ps",
//!   "power": {"scenario": "proportional", "coeff": 1.0, "idle": 0.0},
//!   "objective": "throughput",
//!   "warmup": 2000,
//!   "measure": 20000,
//!   "seed": 7
//! }
//! ```

use crate::error::{Error, Result};
use crate::model::affinity::AffinityMatrix;
use crate::model::energy::PowerScenario;
use crate::model::objective::{Objective, PowerProfile};
use crate::policy::PolicyKind;
use crate::sim::distribution::Distribution;
use crate::sim::dynamic::{DynamicConfig, FaultPlan, ResolveMode, Trigger};
use crate::sim::engine::SimConfig;
use crate::sim::processor::Discipline;
use crate::sim::workload::{churn_fault_plan, scenario_phases, ScenarioKind, ScenarioParams};

use super::json::Json;

/// One fully specified simulation experiment.
#[derive(Debug, Clone)]
pub struct ExperimentSpec {
    /// Affinity matrix.
    pub mu: AffinityMatrix,
    /// Policy to run.
    pub policy: PolicyKind,
    /// Simulation parameters.
    pub sim: SimConfig,
}

impl ExperimentSpec {
    /// Parse and validate from JSON text.
    pub fn from_json(text: &str) -> Result<Self> {
        let j = Json::parse(text)?;

        let mu_rows: Vec<Vec<f64>> = j
            .req("mu")?
            .as_arr()?
            .iter()
            .map(|row| row.as_arr()?.iter().map(Json::as_f64).collect())
            .collect::<Result<_>>()?;
        let mu = AffinityMatrix::from_rows(&mu_rows)?;

        let populations: Vec<u32> = j
            .req("populations")?
            .as_arr()?
            .iter()
            // srclint: allow(as-truncation) — population counts are config-scale; a value beyond u32 is not a meaningful scenario
            .map(|v| Ok(v.as_u64()? as u32))
            .collect::<Result<_>>()?;

        let policy = PolicyKind::parse(j.req("policy")?.as_str()?)?;
        let dist = match j.get("distribution") {
            Some(v) => Distribution::parse(v.as_str()?)?,
            None => Distribution::Exponential,
        };
        let discipline = match j.get("discipline") {
            Some(v) => Discipline::parse(v.as_str()?)?,
            None => Discipline::Ps,
        };
        let (power, power_coeff, idle_power) = match j.get("power") {
            Some(p) => parse_power_block(p)?,
            None => (PowerScenario::Proportional, 1.0, 0.0),
        };
        let objective = match j.get("objective") {
            Some(v) => {
                let o = Objective::parse(v.as_str()?)?;
                o.validate()?;
                o
            }
            None => Objective::Throughput,
        };

        let mut sim = SimConfig::paper_default(populations);
        sim.dist = dist;
        sim.discipline = discipline;
        sim.power = power;
        sim.power_coeff = power_coeff;
        sim.idle_power = idle_power;
        sim.objective = objective;
        if let Some(v) = j.get("warmup") {
            sim.warmup = v.as_u64()?;
        }
        if let Some(v) = j.get("measure") {
            sim.measure = v.as_u64()?;
        }
        if let Some(v) = j.get("seed") {
            sim.seed = v.as_u64()?;
        }

        if sim.populations.len() != mu.types() {
            return Err(Error::Config(format!(
                "{} populations but μ has {} task types",
                sim.populations.len(),
                mu.types()
            )));
        }
        Ok(Self { mu, policy, sim })
    }

    /// Load from a file path.
    pub fn from_file(path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        Self::from_json(&text)
    }
}

/// Parse a `"power"` block — `{"scenario": "constant" | "proportional" |
/// "exponent", "alpha": α, "coeff": k, "idle": f}` — into
/// `(scenario, coeff, idle floor)`; `coeff` defaults to 1, `idle` to 0.
fn parse_power_block(p: &Json) -> Result<(PowerScenario, f64, f64)> {
    let coeff = match p.get("coeff") {
        Some(c) => c.as_f64()?,
        None => 1.0,
    };
    let idle = match p.get("idle") {
        Some(c) => c.as_f64()?,
        None => 0.0,
    };
    let scenario = match p.req("scenario")?.as_str()? {
        // The JSON shape keeps α in its own key; `exponent:<alpha>` is
        // the CLI spelling, also accepted by [`PowerScenario::parse`].
        "exponent" => PowerScenario::Exponent(p.req("alpha")?.as_f64()?),
        name => PowerScenario::parse(name)?,
    };
    Ok((scenario, coeff, idle))
}

/// One fully specified non-stationary scenario experiment
/// (`hetsched scenario --config <file>`).
///
/// JSON shape:
///
/// ```json
/// {
///   "mu": [[20, 15], [3, 8]],
///   "policy": "grin",
///   "scenario": {
///     "kind": "slow_drift",
///     "n": 20, "phases": 6, "completions": 4000, "warmup": 400,
///     "low_eta": 0.2, "high_eta": 0.8,
///     "burst_factor": 2.0,
///     "drift_to": [0.4, 0.2, 5.0, 2.5],
///     "resolve": "adaptive",
///     "drift_threshold": 0.2, "check_every": 250,
///     "trigger": "cusum", "cusum_h": 2.5, "cusum_delta": 0.25,
///     "stale_after": 1000,
///     "shards": 2, "sync_every": 250,
///     "priorities": [4, 1], "deadlines": [1.0, 0],
///     "churn_down": 0.3, "churn_limp": 0.25, "backup_budget": 4,
///     "fault_plan": "down:0@5;up:0@25;limp:1x0.25@40",
///     "objective": "energy",
///     "power": {"scenario": "exponent", "alpha": 0.5, "coeff": 1.0, "idle": 0.0}
///   },
///   "distribution": "exp", "discipline": "ps", "seed": 7
/// }
/// ```
#[derive(Debug, Clone)]
pub struct ScenarioSpec {
    /// Baseline affinity matrix (phases rescale it).
    pub mu: AffinityMatrix,
    /// Policy to run.
    pub policy: PolicyKind,
    /// Which canned regime generated the schedule.
    pub kind: ScenarioKind,
    /// Generator knobs (kept for reporting/round-trips).
    pub params: ScenarioParams,
    /// The fully built dynamic run configuration.
    pub dynamic: DynamicConfig,
}

impl ScenarioSpec {
    /// Parse and validate from JSON text.
    pub fn from_json(text: &str) -> Result<Self> {
        let j = Json::parse(text)?;

        let mu_rows: Vec<Vec<f64>> = j
            .req("mu")?
            .as_arr()?
            .iter()
            .map(|row| row.as_arr()?.iter().map(Json::as_f64).collect())
            .collect::<Result<_>>()?;
        let mu = AffinityMatrix::from_rows(&mu_rows)?;
        let policy = PolicyKind::parse(j.req("policy")?.as_str()?)?;

        let s = j.req("scenario")?;
        let kind = ScenarioKind::parse(s.req("kind")?.as_str()?)?;
        let mut params = ScenarioParams::default();
        if let Some(v) = s.get("n") {
            // srclint: allow(as-truncation) — population counts are config-scale; a value beyond u32 is not a meaningful scenario
            params.n = v.as_u64()? as u32;
        }
        if let Some(v) = s.get("phases") {
            params.phases = v.as_u64()? as usize;
        }
        if let Some(v) = s.get("completions") {
            params.completions = v.as_u64()?;
        }
        if let Some(v) = s.get("warmup") {
            params.warmup = v.as_u64()?;
        }
        if let Some(v) = s.get("low_eta") {
            params.low_eta = v.as_f64()?;
        }
        if let Some(v) = s.get("high_eta") {
            params.high_eta = v.as_f64()?;
        }
        if let Some(v) = s.get("burst_factor") {
            params.burst_factor = v.as_f64()?;
        }
        if let Some(v) = s.get("drift_to") {
            params.drift_to =
                v.as_arr()?.iter().map(Json::as_f64).collect::<Result<_>>()?;
        }
        if let Some(v) = s.get("churn_down") {
            params.churn_down = v.as_f64()?;
        }
        if let Some(v) = s.get("churn_limp") {
            params.churn_limp = v.as_f64()?;
        }
        if let Some(v) = s.get("backup_budget") {
            // srclint: allow(as-truncation) — backup budgets are config-scale; a value beyond u32 is not a meaningful scenario
            params.backup_budget = v.as_u64()? as u32;
        }

        let mut dynamic = DynamicConfig::new(scenario_phases(kind, &params)?);
        // Scenario surfaces (JSON and `hetsched scenario` flags) default
        // to the adaptive mode — the subsystem under study; the oracle
        // and frozen modes are explicit opt-ins.
        dynamic.resolve = ResolveMode::Adaptive;
        if let Some(v) = s.get("resolve") {
            dynamic.resolve = ResolveMode::parse(v.as_str()?)?;
        }
        if let Some(v) = s.get("drift_threshold") {
            dynamic.drift.threshold = v.as_f64()?;
        }
        if let Some(v) = s.get("check_every") {
            dynamic.drift.check_every = v.as_u64()?;
        }
        if let Some(v) = s.get("trigger") {
            dynamic.drift.trigger = Trigger::parse(v.as_str()?)?;
        }
        if let Some(v) = s.get("cusum_h") {
            dynamic.drift.cusum_h = v.as_f64()?;
        }
        if let Some(v) = s.get("cusum_delta") {
            dynamic.drift.cusum_delta = v.as_f64()?;
        }
        if let Some(v) = s.get("stale_after") {
            dynamic.drift.stale_after = v.as_u64()?;
        }
        if let Some(v) = s.get("shards") {
            dynamic.shard.shards = v.as_u64()? as usize;
        }
        if let Some(v) = s.get("sync_every") {
            dynamic.shard.sync_every = v.as_u64()?;
        }
        if let Some(v) = s.get("priorities") {
            dynamic.priorities = v
                .as_arr()?
                .iter()
                // srclint: allow(as-truncation) — population counts are config-scale; a value beyond u32 is not a meaningful scenario
                .map(|x| Ok(x.as_u64()? as u32))
                .collect::<Result<_>>()?;
        }
        if let Some(v) = s.get("deadlines") {
            dynamic.deadlines =
                v.as_arr()?.iter().map(Json::as_f64).collect::<Result<_>>()?;
        }
        if let Some(v) = s.get("objective") {
            dynamic.objective = Objective::parse(v.as_str()?)?;
            dynamic.objective.validate()?;
        }
        if let Some(p) = s.get("power") {
            let (scenario, coeff, idle) = parse_power_block(p)?;
            let profile = PowerProfile::new(coeff, scenario).with_idle(idle);
            profile.validate()?;
            dynamic.power = profile;
        }
        // Failure/recovery schedule: an explicit spec wins; a churn
        // scenario without one gets the auto-built schedule that
        // matches its phases.
        if let Some(v) = s.get("fault_plan") {
            let mut plan = FaultPlan::parse_spec(v.as_str()?)?;
            plan.validate(mu.procs())?;
            if s.get("backup_budget").is_some() {
                plan.backup_budget = params.backup_budget;
            }
            dynamic.faults = plan;
        } else if kind == ScenarioKind::Churn {
            dynamic.faults = churn_fault_plan(&mu, &params)?;
        }
        if let Some(v) = j.get("distribution") {
            dynamic.dist = Distribution::parse(v.as_str()?)?;
        }
        if let Some(v) = j.get("discipline") {
            dynamic.discipline = Discipline::parse(v.as_str()?)?;
        }
        if let Some(v) = j.get("seed") {
            dynamic.seed = v.as_u64()?;
        }

        if mu.types() != 2 {
            return Err(Error::Config(format!(
                "canned scenarios are two-type; μ has {} task types",
                mu.types()
            )));
        }
        Ok(Self { mu, policy, kind, params, dynamic })
    }

    /// Load from a file path.
    pub fn from_file(path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        Self::from_json(&text)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SPEC: &str = r#"{
        "mu": [[20, 15], [3, 8]],
        "populations": [10, 10],
        "policy": "cab",
        "distribution": "pareto",
        "discipline": "fcfs",
        "power": {"scenario": "constant", "coeff": 2.5},
        "warmup": 100,
        "measure": 1000,
        "seed": 42
    }"#;

    #[test]
    fn parses_full_spec() {
        let s = ExperimentSpec::from_json(SPEC).unwrap();
        assert_eq!(s.policy, PolicyKind::Cab);
        assert_eq!(s.mu.rate(0, 0), 20.0);
        assert_eq!(s.sim.populations, vec![10, 10]);
        assert_eq!(s.sim.discipline, Discipline::Fcfs);
        assert_eq!(s.sim.warmup, 100);
        assert_eq!(s.sim.seed, 42);
        assert_eq!(s.sim.power_coeff, 2.5);
        assert_eq!(s.sim.power, PowerScenario::Constant);
    }

    #[test]
    fn defaults_apply() {
        let s = ExperimentSpec::from_json(
            r#"{"mu": [[2,1],[1,2]], "populations": [3,3], "policy": "grin"}"#,
        )
        .unwrap();
        assert_eq!(s.sim.dist, Distribution::Exponential);
        assert_eq!(s.sim.discipline, Discipline::Ps);
        assert_eq!(s.sim.power, PowerScenario::Proportional);
    }

    #[test]
    fn scenario_spec_parses_all_three_kinds() {
        use crate::sim::processor::Discipline;
        // Phase-shift: full knob coverage.
        let s = ScenarioSpec::from_json(
            r#"{
            "mu": [[20, 15], [3, 8]],
            "policy": "grin",
            "scenario": {
                "kind": "phase_shift",
                "n": 12, "phases": 4, "completions": 500, "warmup": 50,
                "low_eta": 0.25, "high_eta": 0.75,
                "resolve": "adaptive",
                "drift_threshold": 0.3, "check_every": 100
            },
            "distribution": "uniform", "discipline": "fcfs", "seed": 42
        }"#,
        )
        .unwrap();
        assert_eq!(s.kind, ScenarioKind::PhaseShift);
        assert_eq!(s.policy, PolicyKind::GrIn);
        assert_eq!(s.params.n, 12);
        assert_eq!(s.dynamic.phases.len(), 4);
        assert_eq!(s.dynamic.resolve, ResolveMode::Adaptive);
        assert_eq!(s.dynamic.drift.check_every, 100);
        assert!((s.dynamic.drift.threshold - 0.3).abs() < 1e-12);
        assert_eq!(s.dynamic.dist, Distribution::Uniform);
        assert_eq!(s.dynamic.discipline, Discipline::Fcfs);
        assert_eq!(s.dynamic.seed, 42);
        // The parsed schedule equals the builder's output.
        let want = scenario_phases(s.kind, &s.params).unwrap();
        for (a, b) in s.dynamic.phases.iter().zip(&want) {
            assert_eq!(a.populations, b.populations);
            assert_eq!(a.completions, b.completions);
        }

        // Burst: population surge phases present.
        let s = ScenarioSpec::from_json(
            r#"{
            "mu": [[20, 15], [3, 8]],
            "policy": "cab",
            "scenario": {"kind": "burst", "phases": 3, "burst_factor": 3.0}
        }"#,
        )
        .unwrap();
        assert_eq!(s.kind, ScenarioKind::Burst);
        // No "resolve" key: the scenario surface defaults to adaptive,
        // matching the `hetsched scenario` flag default.
        assert_eq!(s.dynamic.resolve, ResolveMode::Adaptive);
        let totals: Vec<u32> = s
            .dynamic
            .phases
            .iter()
            .map(|p| p.populations.iter().sum())
            .collect();
        assert_eq!(totals, vec![20, 20, 60]);

        // Slow drift: custom drift target threads through.
        let s = ScenarioSpec::from_json(
            r#"{
            "mu": [[20, 15], [3, 8]],
            "policy": "grin",
            "scenario": {"kind": "slow_drift", "phases": 2,
                         "drift_to": [0.5, 1.0], "resolve": "static"}
        }"#,
        )
        .unwrap();
        assert_eq!(s.kind, ScenarioKind::SlowDrift);
        assert_eq!(s.dynamic.resolve, ResolveMode::Static);
        assert_eq!(s.dynamic.phases[1].mu_scale, vec![0.5, 1.0]);
        // The "trigger" key defaults to the polled threshold.
        assert_eq!(s.dynamic.drift.trigger, Trigger::Threshold);

        // Abrupt flip + CUSUM trigger: the change-point knobs thread
        // through to the DriftConfig.
        let s = ScenarioSpec::from_json(
            r#"{
            "mu": [[20, 15], [3, 8]],
            "policy": "grin",
            "scenario": {"kind": "abrupt_flip", "phases": 3,
                         "trigger": "cusum", "cusum_h": 3.0,
                         "cusum_delta": 0.5, "stale_after": 400}
        }"#,
        )
        .unwrap();
        assert_eq!(s.kind, ScenarioKind::AbruptFlip);
        assert_eq!(s.dynamic.drift.trigger, Trigger::Cusum);
        assert!((s.dynamic.drift.cusum_h - 3.0).abs() < 1e-12);
        assert!((s.dynamic.drift.cusum_delta - 0.5).abs() < 1e-12);
        assert_eq!(s.dynamic.drift.stale_after, 400);
        assert!(s.dynamic.phases[0].mu_scale.is_empty());
        assert!(!s.dynamic.phases[2].mu_scale.is_empty());
    }

    #[test]
    fn scenario_spec_parses_priority_mix_and_priority_keys() {
        let s = ScenarioSpec::from_json(
            r#"{
            "mu": [[30, 3.5], [31, 16]],
            "policy": "grin",
            "scenario": {"kind": "priority_mix", "phases": 4,
                         "priorities": [4, 1], "deadlines": [1.0, 0]}
        }"#,
        )
        .unwrap();
        assert_eq!(s.kind, ScenarioKind::PriorityMix);
        assert_eq!(s.dynamic.priorities, vec![4, 1]);
        assert_eq!(s.dynamic.deadlines, vec![1.0, 0.0]);
        assert_eq!(s.dynamic.phases.len(), 4);
        // Offered load flips at the midpoint; rates never change.
        assert_ne!(s.dynamic.phases[0].populations, s.dynamic.phases[3].populations);
        assert!(s.dynamic.phases.iter().all(|p| p.mu_scale.is_empty()));
        // Without the keys both axes default to off.
        let s = ScenarioSpec::from_json(
            r#"{"mu": [[2,1],[1,2]], "policy": "grin",
                "scenario": {"kind": "burst"}}"#,
        )
        .unwrap();
        assert!(s.dynamic.priorities.is_empty());
        assert!(s.dynamic.deadlines.is_empty());
    }

    #[test]
    fn scenario_spec_parses_churn_and_fault_plans() {
        use crate::sim::dynamic::FaultKind;
        // A churn scenario auto-builds its matching fault plan from the
        // churn knobs.
        let s = ScenarioSpec::from_json(
            r#"{
            "mu": [[20, 15], [3, 8]],
            "policy": "grin",
            "scenario": {"kind": "churn", "phases": 4,
                         "churn_down": 0.4, "churn_limp": 0.5,
                         "backup_budget": 6}
        }"#,
        )
        .unwrap();
        assert_eq!(s.kind, ScenarioKind::Churn);
        assert!((s.params.churn_down - 0.4).abs() < 1e-12);
        assert!((s.params.churn_limp - 0.5).abs() < 1e-12);
        assert_eq!(s.params.backup_budget, 6);
        assert!(!s.dynamic.faults.is_empty());
        assert_eq!(s.dynamic.faults.backup_budget, 6);
        assert_eq!(s.dynamic.faults, churn_fault_plan(&s.mu, &s.params).unwrap());
        // The auto plan round-trips through the spec grammar.
        let spec = s.dynamic.faults.to_spec();
        assert_eq!(FaultPlan::parse_spec(&spec).unwrap(), s.dynamic.faults);

        // An explicit fault_plan overrides the auto schedule, and the
        // scenario-level backup_budget overrides the spec's.
        let s = ScenarioSpec::from_json(
            r#"{
            "mu": [[20, 15], [3, 8]],
            "policy": "grin",
            "scenario": {"kind": "churn", "phases": 2,
                         "fault_plan": "down:0@5;up:0@25;limp:1x0.25@40;budget:1",
                         "backup_budget": 9}
        }"#,
        )
        .unwrap();
        assert_eq!(s.dynamic.faults.events.len(), 3);
        assert_eq!(s.dynamic.faults.events[0].kind, FaultKind::Down);
        assert_eq!(s.dynamic.faults.events[2].kind, FaultKind::Limp(0.25));
        assert_eq!(s.dynamic.faults.backup_budget, 9);

        // Explicit plans also attach to non-churn kinds (fault-injected
        // variants of any canned regime)...
        let s = ScenarioSpec::from_json(
            r#"{
            "mu": [[20, 15], [3, 8]],
            "policy": "grin",
            "scenario": {"kind": "phase_shift", "fault_plan": "down:1@10;up:1@20"}
        }"#,
        )
        .unwrap();
        assert_eq!(s.kind, ScenarioKind::PhaseShift);
        assert_eq!(s.dynamic.faults.events.len(), 2);
        // ...while non-churn kinds without one stay fault-free.
        let s = ScenarioSpec::from_json(
            r#"{"mu": [[2,1],[1,2]], "policy": "grin",
                "scenario": {"kind": "burst"}}"#,
        )
        .unwrap();
        assert!(s.dynamic.faults.is_empty());

        // Bad documents are rejected loudly: unparseable specs, events
        // addressing devices the fleet doesn't have, bad churn knobs.
        assert!(ScenarioSpec::from_json(
            r#"{"mu": [[2,1],[1,2]], "policy": "grin",
                "scenario": {"kind": "burst", "fault_plan": "explode:0@5"}}"#
        )
        .is_err());
        assert!(ScenarioSpec::from_json(
            r#"{"mu": [[2,1],[1,2]], "policy": "grin",
                "scenario": {"kind": "burst", "fault_plan": "down:7@5"}}"#
        )
        .is_err());
        assert!(ScenarioSpec::from_json(
            r#"{"mu": [[20,15],[3,8]], "policy": "grin",
                "scenario": {"kind": "churn", "churn_down": 0.95}}"#
        )
        .is_err());
    }

    #[test]
    fn scenario_spec_parses_saturation() {
        // The overload ramp rides the generic scenario grammar: the
        // burst_factor key doubles as the per-phase load multiplier.
        let s = ScenarioSpec::from_json(
            r#"{
            "mu": [[20, 15], [3, 8]],
            "policy": "grin",
            "scenario": {"kind": "saturation", "n": 8, "phases": 3,
                         "burst_factor": 4.0}
        }"#,
        )
        .unwrap();
        assert_eq!(s.kind, ScenarioKind::Saturation);
        let phases = scenario_phases(s.kind, &s.params).unwrap();
        let totals: Vec<u32> =
            phases.iter().map(|p| p.populations.iter().sum()).collect();
        assert_eq!(totals, vec![8, 32, 128]);
        // A non-ramping factor fails the phase builder, which from_json
        // runs eagerly — the document is rejected at parse time.
        assert!(ScenarioSpec::from_json(
            r#"{"mu": [[20, 15], [3, 8]], "policy": "grin",
                "scenario": {"kind": "saturation", "burst_factor": 1.0}}"#,
        )
        .is_err());
    }

    #[test]
    fn scenario_spec_rejects_bad_documents() {
        // Unknown kind.
        assert!(ScenarioSpec::from_json(
            r#"{"mu": [[2,1],[1,2]], "policy": "cab",
                "scenario": {"kind": "steady"}}"#
        )
        .is_err());
        // Unknown resolve mode.
        assert!(ScenarioSpec::from_json(
            r#"{"mu": [[2,1],[1,2]], "policy": "cab",
                "scenario": {"kind": "burst", "resolve": "sometimes"}}"#
        )
        .is_err());
        // Unknown trigger.
        assert!(ScenarioSpec::from_json(
            r#"{"mu": [[2,1],[1,2]], "policy": "cab",
                "scenario": {"kind": "burst", "trigger": "vibes"}}"#
        )
        .is_err());
        // Missing scenario block.
        assert!(ScenarioSpec::from_json(
            r#"{"mu": [[2,1],[1,2]], "policy": "cab"}"#
        )
        .is_err());
        // Non-two-type matrix.
        assert!(ScenarioSpec::from_json(
            r#"{"mu": [[2,1],[1,2],[3,3]], "policy": "grin",
                "scenario": {"kind": "burst"}}"#
        )
        .is_err());
    }

    #[test]
    fn energy_keys_round_trip_through_both_specs() {
        // ExperimentSpec: objective + full power block (idle included).
        let s = ExperimentSpec::from_json(
            r#"{
            "mu": [[20, 15], [3, 8]], "populations": [10, 10], "policy": "grin",
            "objective": "edp",
            "power": {"scenario": "exponent", "alpha": 0.5, "coeff": 2.0, "idle": 0.25}
        }"#,
        )
        .unwrap();
        assert_eq!(s.sim.objective, Objective::Edp);
        assert_eq!(s.sim.power, PowerScenario::Exponent(0.5));
        assert_eq!(s.sim.power_coeff, 2.0);
        assert_eq!(s.sim.idle_power, 0.25);
        // The parsed spec reassembles into the exact profile the engine
        // will meter with.
        assert_eq!(
            s.sim.power_profile(),
            PowerProfile::new(2.0, PowerScenario::Exponent(0.5)).with_idle(0.25)
        );
        // ScenarioSpec: the scenario block carries the same axes.
        let s = ScenarioSpec::from_json(
            r#"{
            "mu": [[20, 15], [3, 8]], "policy": "grin",
            "scenario": {"kind": "slow_drift", "phases": 2,
                         "objective": "energy",
                         "power": {"scenario": "constant", "coeff": 3.0, "idle": 0.5}}
        }"#,
        )
        .unwrap();
        assert_eq!(s.dynamic.objective, Objective::EnergyPerTask);
        assert_eq!(
            s.dynamic.power,
            PowerProfile::new(3.0, PowerScenario::Constant).with_idle(0.5)
        );
        // Omitted keys default to the pre-objective behavior.
        let s = ScenarioSpec::from_json(
            r#"{"mu": [[2,1],[1,2]], "policy": "grin",
                "scenario": {"kind": "burst"}}"#,
        )
        .unwrap();
        assert_eq!(s.dynamic.objective, Objective::Throughput);
        assert_eq!(s.dynamic.power, PowerProfile::default());
        // Bad values are rejected loudly.
        assert!(ExperimentSpec::from_json(
            r#"{"mu": [[2,1],[1,2]], "populations": [3,3], "policy": "grin",
                "objective": "vibes"}"#
        )
        .is_err());
        assert!(ExperimentSpec::from_json(
            r#"{"mu": [[2,1],[1,2]], "populations": [3,3], "policy": "grin",
                "objective": "tpw:1.5"}"#
        )
        .is_err());
        assert!(ScenarioSpec::from_json(
            r#"{"mu": [[2,1],[1,2]], "policy": "grin",
                "scenario": {"kind": "burst",
                             "power": {"scenario": "exponent", "alpha": 1.5}}}"#
        )
        .is_err());
    }

    #[test]
    fn rejects_arity_mismatch_and_bad_policy() {
        assert!(ExperimentSpec::from_json(
            r#"{"mu": [[2,1],[1,2]], "populations": [3], "policy": "cab"}"#
        )
        .is_err());
        assert!(ExperimentSpec::from_json(
            r#"{"mu": [[2,1],[1,2]], "populations": [3,3], "policy": "wat"}"#
        )
        .is_err());
        assert!(ExperimentSpec::from_json(
            r#"{"mu": [[2,1],[1,2]], "populations": [3,3], "policy": "cab",
                "power": {"scenario": "quadratic"}}"#
        )
        .is_err());
    }
}
