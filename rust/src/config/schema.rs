//! Typed experiment configuration consumed by the CLI launcher.
//!
//! JSON shape (see `examples/configs/` for shipped specs):
//!
//! ```json
//! {
//!   "mu": [[20, 15], [3, 8]],
//!   "populations": [10, 10],
//!   "policy": "cab",
//!   "distribution": "exp",
//!   "discipline": "ps",
//!   "power": {"scenario": "proportional", "coeff": 1.0},
//!   "warmup": 2000,
//!   "measure": 20000,
//!   "seed": 7
//! }
//! ```

use crate::error::{Error, Result};
use crate::model::affinity::AffinityMatrix;
use crate::model::energy::PowerScenario;
use crate::policy::PolicyKind;
use crate::sim::distribution::Distribution;
use crate::sim::engine::SimConfig;
use crate::sim::processor::Discipline;

use super::json::Json;

/// One fully specified simulation experiment.
#[derive(Debug, Clone)]
pub struct ExperimentSpec {
    /// Affinity matrix.
    pub mu: AffinityMatrix,
    /// Policy to run.
    pub policy: PolicyKind,
    /// Simulation parameters.
    pub sim: SimConfig,
}

impl ExperimentSpec {
    /// Parse and validate from JSON text.
    pub fn from_json(text: &str) -> Result<Self> {
        let j = Json::parse(text)?;

        let mu_rows: Vec<Vec<f64>> = j
            .req("mu")?
            .as_arr()?
            .iter()
            .map(|row| row.as_arr()?.iter().map(Json::as_f64).collect())
            .collect::<Result<_>>()?;
        let mu = AffinityMatrix::from_rows(&mu_rows)?;

        let populations: Vec<u32> = j
            .req("populations")?
            .as_arr()?
            .iter()
            .map(|v| Ok(v.as_u64()? as u32))
            .collect::<Result<_>>()?;

        let policy = PolicyKind::parse(j.req("policy")?.as_str()?)?;
        let dist = match j.get("distribution") {
            Some(v) => Distribution::parse(v.as_str()?)?,
            None => Distribution::Exponential,
        };
        let discipline = match j.get("discipline") {
            Some(v) => Discipline::parse(v.as_str()?)?,
            None => Discipline::Ps,
        };
        let (power, power_coeff) = match j.get("power") {
            Some(p) => {
                let coeff = match p.get("coeff") {
                    Some(c) => c.as_f64()?,
                    None => 1.0,
                };
                let scenario = match p.req("scenario")?.as_str()? {
                    "constant" => PowerScenario::Constant,
                    "proportional" => PowerScenario::Proportional,
                    "exponent" => PowerScenario::Exponent(p.req("alpha")?.as_f64()?),
                    other => {
                        return Err(Error::Parse(format!(
                            "unknown power scenario '{other}'"
                        )))
                    }
                };
                (scenario, coeff)
            }
            None => (PowerScenario::Proportional, 1.0),
        };

        let mut sim = SimConfig::paper_default(populations);
        sim.dist = dist;
        sim.discipline = discipline;
        sim.power = power;
        sim.power_coeff = power_coeff;
        if let Some(v) = j.get("warmup") {
            sim.warmup = v.as_u64()?;
        }
        if let Some(v) = j.get("measure") {
            sim.measure = v.as_u64()?;
        }
        if let Some(v) = j.get("seed") {
            sim.seed = v.as_u64()?;
        }

        if sim.populations.len() != mu.types() {
            return Err(Error::Config(format!(
                "{} populations but μ has {} task types",
                sim.populations.len(),
                mu.types()
            )));
        }
        Ok(Self { mu, policy, sim })
    }

    /// Load from a file path.
    pub fn from_file(path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        Self::from_json(&text)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SPEC: &str = r#"{
        "mu": [[20, 15], [3, 8]],
        "populations": [10, 10],
        "policy": "cab",
        "distribution": "pareto",
        "discipline": "fcfs",
        "power": {"scenario": "constant", "coeff": 2.5},
        "warmup": 100,
        "measure": 1000,
        "seed": 42
    }"#;

    #[test]
    fn parses_full_spec() {
        let s = ExperimentSpec::from_json(SPEC).unwrap();
        assert_eq!(s.policy, PolicyKind::Cab);
        assert_eq!(s.mu.rate(0, 0), 20.0);
        assert_eq!(s.sim.populations, vec![10, 10]);
        assert_eq!(s.sim.discipline, Discipline::Fcfs);
        assert_eq!(s.sim.warmup, 100);
        assert_eq!(s.sim.seed, 42);
        assert_eq!(s.sim.power_coeff, 2.5);
        assert_eq!(s.sim.power, PowerScenario::Constant);
    }

    #[test]
    fn defaults_apply() {
        let s = ExperimentSpec::from_json(
            r#"{"mu": [[2,1],[1,2]], "populations": [3,3], "policy": "grin"}"#,
        )
        .unwrap();
        assert_eq!(s.sim.dist, Distribution::Exponential);
        assert_eq!(s.sim.discipline, Discipline::Ps);
        assert_eq!(s.sim.power, PowerScenario::Proportional);
    }

    #[test]
    fn rejects_arity_mismatch_and_bad_policy() {
        assert!(ExperimentSpec::from_json(
            r#"{"mu": [[2,1],[1,2]], "populations": [3], "policy": "cab"}"#
        )
        .is_err());
        assert!(ExperimentSpec::from_json(
            r#"{"mu": [[2,1],[1,2]], "populations": [3,3], "policy": "wat"}"#
        )
        .is_err());
        assert!(ExperimentSpec::from_json(
            r#"{"mu": [[2,1],[1,2]], "populations": [3,3], "policy": "cab",
                "power": {"scenario": "quadratic"}}"#
        )
        .is_err());
    }
}
