//! The §3.3 Continuous-Time Markov Chain analysis (Fig. 3).
//!
//! Under exponential task sizes the two-type closed network is a CTMC
//! over the (N_s = (N1+1)(N2+1)) states S = (N11, N22).  The paper's
//! "general method" (§3.3): (i) write the balance equations for a given
//! routing policy r, (ii) solve for the limiting probabilities p(S),
//! (iii) X_sys = Σ p(S)·X(S) (Eq. 9), (iv) optimize over r.
//!
//! We implement (i)–(iii) exactly, for any *deterministic stationary*
//! routing policy expressed as "where does the next i-type task go in
//! state S".  This gives an analytic (simulation-free) throughput for
//! every policy on small systems and verifies Lemma 2 numerically: the
//! CAB routing concentrates all probability mass on S_max, and no policy
//! exceeds max_S X(S).
//!
//! Transition structure (PS service, exponential sizes, mean 1): in
//! state S a resident i-type task on processor j completes with rate
//! μ_ij·N_ij/occ_j (Eq. 5 summed over the N_ij tasks).  The completed
//! program immediately re-issues an i-type task, routed by the policy —
//! so a completion of (i, j) moves the system to the state with that
//! task at policy(i, S′).

// srclint: allow-file(index-reachable) — state vectors are sized by the enumerated state count; indices are enumerated states

use super::affinity::AffinityMatrix;
use super::state::StateMatrix;
use super::throughput::x_of_state;
use crate::error::{Error, Result};
use crate::solver::linalg::Mat;

/// A stationary routing rule: given the task type that just departed and
/// the intermediate state (task removed), return the destination
/// processor (deterministic) or a probability split (`route_probs`).
///
/// **Reducibility caveat** (a real phenomenon this module exposed in our
/// own simulator): *deterministic* routings frequently make the closed
/// chain reducible — several disjoint recurrent classes, each with its
/// own long-run throughput, selected by the initial fill.  The Eq.-10
/// bound X_sys ≤ max X(S) holds for every class, so Lemma-2 checks remain
/// valid, but a DES cross-validation must either pin the initial state or
/// use a probabilistic (irreducible) routing such as [`RandomRouting`].
pub trait Routing {
    /// Destination processor for the re-issued i-type task.
    fn route(&self, ttype: usize, intermediate: &StateMatrix) -> usize;

    /// Probability of each destination (defaults to the deterministic
    /// choice).  `probs.len() == l`; must sum to 1.
    fn route_probs(&self, ttype: usize, intermediate: &StateMatrix, probs: &mut [f64]) {
        probs.iter_mut().for_each(|p| *p = 0.0);
        probs[self.route(ttype, intermediate)] = 1.0;
    }
}

impl<F: Fn(usize, &StateMatrix) -> usize> Routing for F {
    fn route(&self, ttype: usize, intermediate: &StateMatrix) -> usize {
        self(ttype, intermediate)
    }
}

/// The §5 RD baseline: uniform random dispatch.  Probabilistic ⇒ the
/// chain is irreducible and the stationary distribution unique, which
/// makes RD the right routing for CTMC-vs-simulation cross-validation.
pub struct RandomRouting;

impl Routing for RandomRouting {
    fn route(&self, _ttype: usize, _inter: &StateMatrix) -> usize {
        0 // unused: route_probs overrides
    }

    fn route_probs(&self, _ttype: usize, _inter: &StateMatrix, probs: &mut [f64]) {
        let p = 1.0 / probs.len() as f64;
        probs.iter_mut().for_each(|v| *v = p);
    }
}

/// CTMC analysis result.
#[derive(Debug, Clone)]
pub struct CtmcSolution {
    /// Limiting probability of each (N11, N22) state, row-major over
    /// N11-major order (index = n11·(N2+1) + n22).
    pub p: Vec<f64>,
    /// Analytic long-run throughput Σ p(S)·X(S) (Eq. 9).
    pub throughput: f64,
    /// max_S X(S) over the reachable chain (Lemma 2's bound).
    pub x_max: f64,
    /// Population parameters.
    pub n1: u32,
    /// Population parameters.
    pub n2: u32,
}

/// Build and solve the CTMC for a 2×2 system under a routing policy.
pub fn solve(
    mu: &AffinityMatrix,
    n1: u32,
    n2: u32,
    routing: &dyn Routing,
) -> Result<CtmcSolution> {
    if mu.types() != 2 || mu.procs() != 2 {
        return Err(Error::Shape("CTMC analysis is for 2x2 systems".into()));
    }
    if n1 + n2 == 0 {
        return Err(Error::Config("empty system".into()));
    }
    let dim = ((n1 + 1) * (n2 + 1)) as usize;
    let idx = |a: u32, b: u32| -> usize { (a * (n2 + 1) + b) as usize };

    // Generator matrix Q (row = from-state): Q[s][t] = rate s→t.
    let mut q = Mat::zeros(dim, dim);
    for a in 0..=n1 {
        for b in 0..=n2 {
            let s = StateMatrix::from_two_type(a, b, n1, n2)?;
            let from = idx(a, b);
            // Completion of an i-type task on processor j.
            for i in 0..2usize {
                for j in 0..2usize {
                    let nij = s.get(i, j);
                    if nij == 0 {
                        continue;
                    }
                    let occ = s.col_sum(j);
                    let rate = mu.rate(i, j) * nij as f64 / occ as f64;
                    // Intermediate state: the task leaves cell (i, j).
                    let mut inter = s.clone();
                    inter.dec(i, j)?;
                    // Policy re-issues the i-type task (possibly split
                    // probabilistically across destinations).
                    let mut probs = [0.0f64; 2];
                    routing.route_probs(i, &inter, &mut probs);
                    debug_assert!((probs.iter().sum::<f64>() - 1.0).abs() < 1e-12);
                    for (dest, &pr) in probs.iter().enumerate() {
                        if pr == 0.0 {
                            continue;
                        }
                        let mut next = inter.clone();
                        next.inc(i, dest);
                        let (na, nb) = (next.get(0, 0), next.get(1, 1));
                        let to = idx(na, nb);
                        if to != from {
                            q[(from, to)] += rate * pr;
                        }
                    }
                }
            }
        }
    }
    // Diagonal: Q[s][s] = −Σ_t≠s Q[s][t].
    for s in 0..dim {
        let row_sum: f64 = (0..dim).filter(|&t| t != s).map(|t| q[(s, t)]).sum();
        q[(s, s)] = -row_sum;
    }

    // Solve πQ = 0, Σπ = 1 by uniformization + power iteration:
    // P = I + Q/λ with λ > max |Q_ss| is a stochastic matrix with the
    // same stationary vector.  Routing policies routinely make the chain
    // *reducible* (CAB absorbs into S_max; deterministic rules leave
    // unreachable/transient states), which breaks a direct linear solve;
    // the power iteration started from uniform converges to the unique
    // stationary distribution of the reachable recurrent class instead.
    let lambda = (0..dim)
        .map(|s| -q[(s, s)])
        .fold(0.0f64, f64::max)
        .max(1e-12)
        * 1.05;
    let mut p = vec![1.0 / dim as f64; dim];
    let mut next = vec![0.0f64; dim];
    let mut converged = false;
    for _ in 0..200_000 {
        // next = p · P = p + (p · Q)/λ.
        next.copy_from_slice(&p);
        for s in 0..dim {
            let ps = p[s];
            if ps == 0.0 {
                continue;
            }
            for t in 0..dim {
                let rate = q[(s, t)];
                if rate != 0.0 {
                    next[t] += ps * rate / lambda;
                }
            }
        }
        // Renormalize (guards numerical drift) and test convergence.
        let total: f64 = next.iter().sum();
        let mut delta = 0.0f64;
        for t in 0..dim {
            next[t] /= total;
            delta = delta.max((next[t] - p[t]).abs());
        }
        std::mem::swap(&mut p, &mut next);
        if delta < 1e-13 {
            converged = true;
            break;
        }
    }
    if !converged {
        return Err(Error::Solver("CTMC power iteration did not converge".into()));
    }
    for v in p.iter_mut() {
        if v.abs() < 1e-12 {
            *v = 0.0;
        }
    }

    let mut throughput = 0.0;
    let mut x_max = 0.0f64;
    for a_ in 0..=n1 {
        for b in 0..=n2 {
            let s = StateMatrix::from_two_type(a_, b, n1, n2)?;
            let x = x_of_state(mu, &s);
            x_max = x_max.max(x);
            throughput += p[idx(a_, b)].max(0.0) * x;
        }
    }
    Ok(CtmcSolution { p, throughput, x_max, n1, n2 })
}

/// The CAB routing rule as a [`Routing`] (deficit steering to S_max).
pub struct CabRouting {
    target: StateMatrix,
}

impl CabRouting {
    /// Build from the classified S_max for (n1, n2).
    pub fn new(mu: &AffinityMatrix, n1: u32, n2: u32) -> Result<Self> {
        let (_, target) = crate::policy::cab::Cab::target_state(mu, &[n1, n2])?;
        Ok(Self { target })
    }
}

impl Routing for CabRouting {
    fn route(&self, ttype: usize, inter: &StateMatrix) -> usize {
        // Largest deficit vs target (ties → processor 0 ordering is fine
        // for the 2×2 chain).
        let d0 = self.target.get(ttype, 0) as i64 - inter.get(ttype, 0) as i64;
        let d1 = self.target.get(ttype, 1) as i64 - inter.get(ttype, 1) as i64;
        usize::from(d1 > d0)
    }
}

/// Best-Fit routing.
pub struct BfRouting<'a> {
    mu: &'a AffinityMatrix,
}

impl<'a> BfRouting<'a> {
    /// Route every task to its fastest processor.
    pub fn new(mu: &'a AffinityMatrix) -> Self {
        Self { mu }
    }
}

impl Routing for BfRouting<'_> {
    fn route(&self, ttype: usize, _inter: &StateMatrix) -> usize {
        self.mu.best_proc(ttype)
    }
}

/// Join-the-shortest-queue routing, with the simulator's tie-break
/// (equal occupancy → the task's faster processor).
pub struct JsqRouting<'a> {
    mu: &'a AffinityMatrix,
}

impl<'a> JsqRouting<'a> {
    /// JSQ over a 2×2 system.
    pub fn new(mu: &'a AffinityMatrix) -> Self {
        Self { mu }
    }
}

impl Routing for JsqRouting<'_> {
    fn route(&self, ttype: usize, inter: &StateMatrix) -> usize {
        let (o0, o1) = (inter.col_sum(0), inter.col_sum(1));
        if o0 != o1 {
            usize::from(o1 < o0)
        } else {
            usize::from(self.mu.rate(ttype, 1) > self.mu.rate(ttype, 0))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::affinity::Regime;
    use crate::model::throughput::x_max_theoretical;
    use crate::sim::workload;

    #[test]
    fn probabilities_sum_to_one() {
        let mu = workload::paper_two_type_mu();
        let sol = solve(&mu, 4, 4, &JsqRouting::new(&mu)).unwrap();
        let total: f64 = sol.p.iter().sum();
        assert!((total - 1.0).abs() < 1e-9, "Σp = {total}");
    }

    #[test]
    fn cab_routing_concentrates_on_smax_lemma2() {
        // Under CAB the chain is absorbed in S_max: p(S_max) = 1 and the
        // analytic throughput equals the Eq. 16 optimum exactly.
        let mu = workload::paper_two_type_mu();
        let (n1, n2) = (5u32, 5);
        let cab = CabRouting::new(&mu, n1, n2).unwrap();
        let sol = solve(&mu, n1, n2, &cab).unwrap();
        let want = x_max_theoretical(&mu, Regime::P1Biased, n1, n2);
        assert!(
            (sol.throughput - want).abs() < 1e-8,
            "CTMC X = {} vs Eq.16 {want}",
            sol.throughput
        );
        // All mass on (1, N2).
        let idx = (1 * (n2 + 1) + n2) as usize;
        assert!((sol.p[idx] - 1.0).abs() < 1e-8, "p(S_max) = {}", sol.p[idx]);
        // And Lemma 2's bound holds with equality.
        assert!((sol.x_max - want).abs() < 1e-9);
    }

    #[test]
    fn no_routing_beats_xmax_eq9() {
        // Eq. 10: Σ p(S)X(S) ≤ X_max for ANY routing.
        let mu = workload::paper_two_type_mu();
        for routing in [&JsqRouting::new(&mu) as &dyn Routing, &BfRouting::new(&mu)] {
            let sol = solve(&mu, 4, 6, routing).unwrap();
            assert!(
                sol.throughput <= sol.x_max + 1e-9,
                "routing beat X_max: {} > {}",
                sol.throughput,
                sol.x_max
            );
        }
    }

    #[test]
    fn bf_routing_is_suboptimal_in_biased_regime() {
        // The analytic counterpart of the §5 simulation finding.
        let mu = workload::paper_two_type_mu();
        let (n1, n2) = (5u32, 5);
        let cab = solve(&mu, n1, n2, &CabRouting::new(&mu, n1, n2).unwrap()).unwrap();
        let bf = solve(&mu, n1, n2, &BfRouting::new(&mu)).unwrap();
        assert!(
            cab.throughput > bf.throughput + 1e-6,
            "CAB {} vs BF {}",
            cab.throughput,
            bf.throughput
        );
    }

    #[test]
    fn ctmc_matches_simulation_for_random_routing() {
        // Cross-validation: analytic CTMC throughput ≈ simulated
        // throughput under exponential sizes (the §3.3 assumption).
        // RD is probabilistic ⇒ the chain is irreducible and the
        // stationary distribution unique, so the DES must match it from
        // any initial fill.  (Deterministic routings like JSQ split the
        // chain into several recurrent classes — see the trait docs —
        // making this comparison initial-state dependent.)
        use crate::policy::PolicyKind;
        use crate::sim::engine::{ClosedNetwork, SimConfig};
        let mu = workload::paper_two_type_mu();
        let (n1, n2) = (4u32, 4);
        let analytic = solve(&mu, n1, n2, &RandomRouting).unwrap().throughput;
        let mut cfg = SimConfig::paper_default(vec![n1, n2]);
        cfg.warmup = 2_000;
        cfg.measure = 60_000;
        let net = ClosedNetwork::new(&mu, cfg).unwrap();
        let sim = net.run(PolicyKind::Random.build().as_mut()).unwrap().throughput;
        let rel = (analytic - sim).abs() / analytic;
        assert!(rel < 0.03, "CTMC {analytic} vs sim {sim} ({rel:.3})");
    }

    #[test]
    fn jsq_recurrent_class_stays_below_xmax() {
        // JSQ's deterministic chain is reducible; whatever class the
        // uniform-start power iteration mixes over, Eq. 10 bounds it.
        let mu = workload::paper_two_type_mu();
        let sol = solve(&mu, 4, 4, &JsqRouting::new(&mu)).unwrap();
        assert!(sol.throughput <= sol.x_max + 1e-9);
        assert!(sol.throughput > 0.0);
    }

    #[test]
    fn rejects_bad_shapes() {
        let mu3 = crate::model::affinity::AffinityMatrix::from_rows(&[
            vec![1.0, 2.0, 3.0],
            vec![3.0, 2.0, 1.0],
        ])
        .unwrap();
        assert!(solve(&mu3, 2, 2, &JsqRouting::new(&mu3)).is_err());
        let mu = workload::paper_two_type_mu();
        assert!(solve(&mu, 0, 0, &JsqRouting::new(&mu)).is_err());
    }
}
