//! Affinity matrix μ (Def. 3) and the Table-1 regime classification.
//!
//! `μ[i][j]` is the processing rate of an i-type task on a j-type
//! processor (work units / second when running alone).  For the two-type
//! case the paper's affinity constraint (Eq. 2) is `μ11 > μ12` and
//! `μ21 < μ22`; the *relative ordering* of the four entries — never their
//! exact values — selects the optimal policy (Lemma 4).

// srclint: allow-file(index-reachable) — dense k by l parameter matrices validated by the platform check at construction

use crate::error::{Error, Result};

/// Rate assigned to every cell of a dead device's column when masking it
/// out of a believed μ matrix.  [`AffinityMatrix::new`] (correctly)
/// rejects non-positive rates, so "down" is modelled as an ε-rate column:
/// any solver sees essentially zero throughput gain from placing work
/// there, while every matrix invariant (finite, > 0) still holds.
pub const DEAD_RATE: f64 = 1e-9;

/// Dense k×l affinity matrix, row = task type, column = processor type.
#[derive(Debug, Clone, PartialEq)]
pub struct AffinityMatrix {
    k: usize,
    l: usize,
    mu: Vec<f64>,
}

impl AffinityMatrix {
    /// Build from row-major data; all rates must be finite and positive.
    pub fn new(k: usize, l: usize, mu: Vec<f64>) -> Result<Self> {
        if k == 0 || l == 0 || mu.len() != k * l {
            return Err(Error::Shape(format!(
                "affinity matrix {}x{} with {} entries",
                k,
                l,
                mu.len()
            )));
        }
        if mu.iter().any(|&m| !m.is_finite() || m <= 0.0) {
            return Err(Error::Shape(
                "all processing rates must be finite and > 0".into(),
            ));
        }
        Ok(Self { k, l, mu })
    }

    /// Build from rows (each row = one task type across processors).
    pub fn from_rows(rows: &[Vec<f64>]) -> Result<Self> {
        let k = rows.len();
        let l = rows.first().map(|r| r.len()).unwrap_or(0);
        if rows.iter().any(|r| r.len() != l) {
            return Err(Error::Shape("ragged affinity rows".into()));
        }
        Self::new(k, l, rows.concat())
    }

    /// The paper's running two-type example helper.
    pub fn two_type(mu11: f64, mu12: f64, mu21: f64, mu22: f64) -> Result<Self> {
        Self::new(2, 2, vec![mu11, mu12, mu21, mu22])
    }

    /// Number of task types (rows).
    #[inline]
    pub fn types(&self) -> usize {
        self.k
    }

    /// Number of processor types (columns).
    #[inline]
    pub fn procs(&self) -> usize {
        self.l
    }

    /// Rate of i-type task on processor j.
    #[inline]
    pub fn rate(&self, i: usize, j: usize) -> f64 {
        debug_assert!(i < self.k && j < self.l);
        self.mu[i * self.l + j]
    }

    /// Row slice for task type `i`.
    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.mu[i * self.l..(i + 1) * self.l]
    }

    /// Raw row-major data.
    #[inline]
    pub fn data(&self) -> &[f64] {
        &self.mu
    }

    /// The processor on which task type `i` is fastest (Best-Fit target).
    pub fn best_proc(&self, i: usize) -> usize {
        let row = self.row(i);
        let mut best = 0usize;
        for (j, &v) in row.iter().enumerate() {
            if v > row[best] {
                best = j;
            }
        }
        best
    }

    /// Row index of the maximum rate in column `j` ("max j-col μ",
    /// Algorithm 1).
    pub fn max_col_row(&self, j: usize) -> usize {
        let mut best = 0usize;
        for i in 1..self.k {
            if self.rate(i, j) > self.rate(best, j) {
                best = i;
            }
        }
        best
    }

    /// Does the matrix satisfy the two-type affinity constraint (Eq. 2)?
    ///
    /// Only meaningful for 2×2; general matrices use [`Self::best_proc`].
    pub fn satisfies_two_type_affinity(&self) -> bool {
        self.k == 2
            && self.l == 2
            && self.rate(0, 0) > self.rate(0, 1)
            && self.rate(1, 0) < self.rate(1, 1)
    }

    /// Classify a 2×2 system into the Table-1 regime.
    pub fn classify(&self) -> Result<Regime> {
        if self.k != 2 || self.l != 2 {
            return Err(Error::Shape(
                "regime classification is defined for 2x2 systems".into(),
            ));
        }
        let (m11, m12) = (self.rate(0, 0), self.rate(0, 1));
        let (m21, m22) = (self.rate(1, 0), self.rate(1, 1));
        let eq = |a: f64, b: f64| (a - b).abs() <= 1e-12 * a.abs().max(b.abs()).max(1.0);

        // Non-affinity regimes first (rows of Table 1).
        if eq(m11, m22) && eq(m11, m12) && eq(m11, m21) {
            return Ok(Regime::Homogeneous);
        }
        if eq(m11, m21) && eq(m22, m12) && !eq(m11, m22) {
            return Ok(Regime::BigLittleLike);
        }
        if eq(m11, m22) && eq(m12, m21) && m11 > m12 {
            return Ok(Regime::Symmetric);
        }
        // Affinity regimes require Eq. 2.
        if !(m11 > m12 && m21 < m22) {
            return Err(Error::Shape(format!(
                "matrix violates the affinity constraint (Eq. 2): \
                 [[{m11},{m12}],[{m21},{m22}]]"
            )));
        }
        // Vertical (within-column) orderings select the case.
        let left_down = m11 > m21; // processor 1 prefers type-1 tasks
        let right_down = m12 > m22; // processor 2 runs type-1 faster
        match (left_down, right_down) {
            (true, false) => Ok(Regime::GeneralSymmetric),
            (true, true) => Ok(Regime::P1Biased),
            (false, false) => Ok(Regime::P2Biased),
            // Case b.4 of the proof: impossible under Eq. 2
            // (μ21 > μ11 > μ12 > μ22 contradicts μ21 < μ22).
            (false, true) => Err(Error::Shape(
                "invalid affinity ordering (case b.4 of Lemma 4)".into(),
            )),
        }
    }

    /// Power matrix 𝒫_ij = c·μ_ij^α (Def. 4 + the §3.2 exponential
    /// power/performance relation).
    pub fn power_matrix(&self, coeff: f64, alpha: f64) -> Vec<f64> {
        self.mu.iter().map(|&m| coeff * m.powf(alpha)).collect()
    }

    /// Rescaled matrix for non-stationary scenarios:
    ///
    /// * `scale.len() == procs()` — per-processor multipliers (DVFS /
    ///   thermal throttling: a whole column speeds up or slows down);
    /// * `scale.len() == types()·procs()` — per-cell multipliers
    ///   (contention, cache effects: affinities themselves drift).
    ///
    /// All factors must be finite and > 0.
    pub fn scaled(&self, scale: &[f64]) -> Result<AffinityMatrix> {
        if scale.iter().any(|&s| !s.is_finite() || s <= 0.0) {
            return Err(Error::Shape("scale factors must be finite and > 0".into()));
        }
        let data: Vec<f64> = if scale.len() == self.l {
            self.mu
                .iter()
                .enumerate()
                .map(|(c, &m)| m * scale[c % self.l])
                .collect()
        } else if scale.len() == self.k * self.l {
            self.mu.iter().zip(scale).map(|(&m, &s)| m * s).collect()
        } else {
            return Err(Error::Shape(format!(
                "scale has {} factors; need {} (per-processor) or {} (per-cell)",
                scale.len(),
                self.l,
                self.k * self.l
            )));
        };
        Self::new(self.k, self.l, data)
    }

    /// Copy with column `j` replaced by `col` (one rate per task type).
    /// The churn path uses this to restore a recovered device's column
    /// to its boot-time prior.
    pub fn with_column(&self, j: usize, col: &[f64]) -> Result<AffinityMatrix> {
        if j >= self.l {
            return Err(Error::Shape(format!(
                "column {} out of range for {} processors",
                j, self.l
            )));
        }
        if col.len() != self.k {
            return Err(Error::Shape(format!(
                "column has {} rates; need one per task type ({})",
                col.len(),
                self.k
            )));
        }
        let mut data = self.mu.clone();
        for (i, &r) in col.iter().enumerate() {
            data[i * self.l + j] = r;
        }
        Self::new(self.k, self.l, data)
    }

    /// Copy with column `j` masked to [`DEAD_RATE`]: the believed-μ view
    /// of a device marked down.  Re-solving against the masked matrix
    /// steers all traffic to the survivors without violating the
    /// positive-rate invariant.
    pub fn masked_column(&self, j: usize) -> Result<AffinityMatrix> {
        self.with_column(j, &vec![DEAD_RATE; self.k])
    }

    /// Rates of column `j` (one per task type).
    pub fn column(&self, j: usize) -> Vec<f64> {
        debug_assert!(j < self.l);
        (0..self.k).map(|i| self.rate(i, j)).collect()
    }
}

/// The six system regimes of Table 1.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Regime {
    /// μ11 = μ12 = μ21 = μ22: classic SMP; any non-empty split is optimal.
    Homogeneous,
    /// μ11 = μ21, μ12 = μ22, μ11 ≠ μ22: iso-ISA, speed-only heterogeneity.
    BigLittleLike,
    /// μ11 = μ22 ≜ μ1 > μ12 = μ21 ≜ μ2: the symmetric affinity system.
    Symmetric,
    /// μ11 > μ21 and μ22 > μ12: each processor is fastest on "its" task
    /// type → Best-Fit is optimal, S_max = (N1, N2).
    GeneralSymmetric,
    /// μ11 > μ21 and μ12 > μ22: type-1 tasks are faster *everywhere* →
    /// Accelerate-the-Fastest, S_max = (1, N2) (Eq. 16).
    P1Biased,
    /// μ21 > μ11 and μ22 > μ12: type-2 tasks are faster everywhere →
    /// Accelerate-the-Fastest, S_max = (N1, 1) (Eq. 17).
    P2Biased,
}

impl Regime {
    /// Does CAB choose Accelerate-the-Fastest (vs Best-Fit) here?
    pub fn is_biased(self) -> bool {
        matches!(self, Regime::P1Biased | Regime::P2Biased)
    }

    /// Human-readable Table-1 row name.
    pub fn name(self) -> &'static str {
        match self {
            Regime::Homogeneous => "homogeneous",
            Regime::BigLittleLike => "big.LITTLE-like",
            Regime::Symmetric => "symmetric",
            Regime::GeneralSymmetric => "general-symmetric",
            Regime::P1Biased => "P1-biased",
            Regime::P2Biased => "P2-biased",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m(a: f64, b: f64, c: f64, d: f64) -> AffinityMatrix {
        AffinityMatrix::two_type(a, b, c, d).unwrap()
    }

    #[test]
    fn rejects_bad_shapes_and_rates() {
        assert!(AffinityMatrix::new(2, 2, vec![1.0; 3]).is_err());
        assert!(AffinityMatrix::new(0, 2, vec![]).is_err());
        assert!(AffinityMatrix::new(1, 2, vec![1.0, -1.0]).is_err());
        assert!(AffinityMatrix::new(1, 2, vec![1.0, f64::NAN]).is_err());
    }

    #[test]
    fn accessors() {
        let a = m(20.0, 15.0, 3.0, 8.0);
        assert_eq!(a.types(), 2);
        assert_eq!(a.procs(), 2);
        assert_eq!(a.rate(0, 1), 15.0);
        assert_eq!(a.row(1), &[3.0, 8.0]);
        assert_eq!(a.best_proc(0), 0);
        assert_eq!(a.best_proc(1), 1);
        assert_eq!(a.max_col_row(0), 0);
        assert_eq!(a.max_col_row(1), 0); // 15 > 8
    }

    #[test]
    fn classify_paper_cases() {
        // The paper's P1-biased simulation matrix (§5).
        assert_eq!(m(20.0, 15.0, 3.0, 8.0).classify().unwrap(), Regime::P1Biased);
        // General-symmetric: quicksort-500 + NN-2000 (Table 3 rows 1 & 3).
        assert_eq!(
            m(928.0, 3.61, 587.0, 2398.0).classify().unwrap(),
            Regime::GeneralSymmetric
        );
        // P2-biased: quicksort-1000 + NN-2000 (Table 3 rows 2 & 3).
        assert_eq!(
            m(253.0, 0.911, 587.0, 2398.0).classify().unwrap(),
            Regime::P2Biased
        );
        assert_eq!(
            m(5.0, 5.0, 5.0, 5.0).classify().unwrap(),
            Regime::Homogeneous
        );
        assert_eq!(
            m(5.0, 2.0, 5.0, 2.0).classify().unwrap(),
            Regime::BigLittleLike
        );
        assert_eq!(m(5.0, 2.0, 2.0, 5.0).classify().unwrap(), Regime::Symmetric);
    }

    #[test]
    fn classify_rejects_non_affinity_and_b4() {
        // Violates Eq. 2 outright (μ11 < μ12).
        assert!(m(2.0, 5.0, 3.0, 8.0).classify().is_err());
        // Case b.4 cannot be constructed under Eq. 2: μ21 > μ11 and
        // μ12 > μ22 forces μ21 > μ22. Verify the constructor path.
        assert!(m(5.0, 4.0, 6.0, 3.0).classify().is_err());
    }

    #[test]
    fn power_matrix_scenarios() {
        let a = m(20.0, 15.0, 3.0, 8.0);
        // Scenario 1: constant power (α = 0).
        assert_eq!(a.power_matrix(2.0, 0.0), vec![2.0; 4]);
        // Scenario 2: proportional power (α = 1).
        assert_eq!(a.power_matrix(1.0, 1.0), vec![20.0, 15.0, 3.0, 8.0]);
    }

    #[test]
    fn scaled_supports_column_and_cell_factors() {
        let a = m(20.0, 15.0, 3.0, 8.0);
        // Column scaling: processor 0 throttled to half speed.
        let col = a.scaled(&[0.5, 1.0]).unwrap();
        assert_eq!(col.rate(0, 0), 10.0);
        assert_eq!(col.rate(1, 0), 1.5);
        assert_eq!(col.rate(0, 1), 15.0);
        assert_eq!(col.rate(1, 1), 8.0);
        // Cell scaling: arbitrary per-cell drift.
        let cell = a.scaled(&[1.0, 2.0, 3.0, 0.5]).unwrap();
        assert_eq!(cell.rate(0, 1), 30.0);
        assert_eq!(cell.rate(1, 0), 9.0);
        assert_eq!(cell.rate(1, 1), 4.0);
        // Bad arities / factors rejected.
        assert!(a.scaled(&[1.0, 2.0, 3.0]).is_err());
        assert!(a.scaled(&[0.0, 1.0]).is_err());
        assert!(a.scaled(&[f64::NAN, 1.0]).is_err());
    }

    #[test]
    fn masked_column_is_dead_but_valid() {
        let a = m(20.0, 15.0, 3.0, 8.0);
        let masked = a.masked_column(0).unwrap();
        assert_eq!(masked.rate(0, 0), DEAD_RATE);
        assert_eq!(masked.rate(1, 0), DEAD_RATE);
        assert_eq!(masked.rate(0, 1), 15.0);
        assert_eq!(masked.rate(1, 1), 8.0);
        // Restoring the column round-trips to the original matrix.
        let restored = masked.with_column(0, &a.column(0)).unwrap();
        assert_eq!(restored, a);
        // Bounds and arity are enforced.
        assert!(a.masked_column(2).is_err());
        assert!(a.with_column(0, &[1.0]).is_err());
        assert!(a.with_column(0, &[1.0, f64::NAN]).is_err());
    }

    #[test]
    fn regime_helpers() {
        assert!(Regime::P1Biased.is_biased());
        assert!(Regime::P2Biased.is_biased());
        assert!(!Regime::GeneralSymmetric.is_biased());
        assert_eq!(Regime::Symmetric.name(), "symmetric");
    }
}
