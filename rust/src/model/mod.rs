//! Core mathematical model of the paper (§3–§4).
//!
//! * [`affinity`] — the k×l affinity matrix μ (Def. 3), the power matrix
//!   𝒫 = kμ^α (Def. 4) and the six-regime classification of Table 1.
//! * [`state`] — the system state matrix N (Def. 5) and its invariants.
//! * [`throughput`] — X(S): Eq. 4 (two types), Eq. 28 (general), the
//!   partial derivatives (Eqs. 11–12) and the move deltas X_df± used by
//!   GrIn (Eqs. 34, 36).
//! * [`energy`] — expected energy per task (Eq. 19), EDP (Eq. 21) and the
//!   Scenario-1/2 closed forms (Eqs. 22–23) plus the Lemma-7 α-bounds.
//! * [`objective`] — the solve [`objective::Objective`] (throughput, energy,
//!   EDP, throughput-per-watt), the per-device [`objective::PowerProfile`]
//!   and the O(1)-probe objective evaluator driving GrIn's greedy loop.

//! * [`ctmc`] — the §3.3 CTMC (Fig. 3): balance equations → limiting
//!   probabilities → Eq. 9 throughput, for any stationary routing rule.

pub mod affinity;
pub mod ctmc;
pub mod energy;
pub mod objective;
pub mod state;
pub mod throughput;
