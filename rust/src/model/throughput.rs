//! The closed-network throughput function X(S) and its structure.
//!
//! * Eq. 28 (general k×l): `x_of_state`
//! * Eq. 4  (two types, S = (N11, N22)): `x_two_type`
//! * Eqs. 11–12 (partial derivatives): `grad_two_type`
//! * Eqs. 34 / 36 (GrIn move deltas): `x_df_plus` / `x_df_minus`
//! * Eqs. 16–18 (closed-form optima per regime): `x_max_theoretical`
//!
//! Convention: an empty processor contributes zero throughput (0/0 → 0),
//! matching the Pallas `throughput_eval` kernel and the paper's
//! work-conserving reading of Eq. 28.

// srclint: allow-file(index-reachable) — dense k by l rate matrices validated at platform construction

use super::affinity::{AffinityMatrix, Regime};
use super::state::StateMatrix;
use crate::error::{Error, Result};

/// Per-processor throughput X_j = Σ_i μ_ij·N_ij / Σ_i N_ij (Eq. 26/27).
pub fn x_of_proc(mu: &AffinityMatrix, n: &StateMatrix, j: usize) -> f64 {
    let mut num = 0.0;
    let mut den = 0u32;
    for i in 0..mu.types() {
        let nij = n.get(i, j);
        num += mu.rate(i, j) * nij as f64;
        den += nij;
    }
    if den == 0 {
        0.0
    } else {
        num / den as f64
    }
}

/// System throughput X_sys (Eq. 28) for an arbitrary state matrix.
pub fn x_of_state(mu: &AffinityMatrix, n: &StateMatrix) -> f64 {
    debug_assert_eq!(mu.types(), n.types());
    debug_assert_eq!(mu.procs(), n.procs());
    (0..mu.procs()).map(|j| x_of_proc(mu, n, j)).sum()
}

/// Eq. 4: X(N11, N22) for the two-type system with populations (N1, N2).
pub fn x_two_type(
    mu: &AffinityMatrix,
    n11: u32,
    n22: u32,
    n1: u32,
    n2: u32,
) -> Result<f64> {
    if mu.types() != 2 || mu.procs() != 2 {
        return Err(Error::Shape("x_two_type needs a 2x2 matrix".into()));
    }
    let s = StateMatrix::from_two_type(n11, n22, n1, n2)?;
    Ok(x_of_state(mu, &s))
}

/// Eqs. 11–12: (∂X/∂N11, ∂X/∂N22) at a (relaxed, real-valued) state.
pub fn grad_two_type(
    mu: &AffinityMatrix,
    n11: f64,
    n22: f64,
    n1: f64,
    n2: f64,
) -> (f64, f64) {
    let (m11, m12) = (mu.rate(0, 0), mu.rate(0, 1));
    let (m21, m22) = (mu.rate(1, 0), mu.rate(1, 1));
    let d1 = n11 + n2 - n22; // occupancy of P1
    let d2 = n22 + n1 - n11; // occupancy of P2
    let g11 = (m11 - m21) * (n2 - n22) / (d1 * d1) + (m22 - m12) * n22 / (d2 * d2);
    let g22 = (m11 - m21) * n11 / (d1 * d1) + (m22 - m12) * (n1 - n11) / (d2 * d2);
    (g11, g22)
}

/// Eq. 34: throughput delta of *adding* one p-type task to processor j.
#[inline]
pub fn x_df_plus(mu: &AffinityMatrix, n: &StateMatrix, p: usize, j: usize) -> f64 {
    let occ = n.col_sum(j) as f64;
    let xj = x_of_proc(mu, n, j);
    (mu.rate(p, j) - xj) / (occ + 1.0)
}

/// Eq. 36: throughput delta of *removing* one p-type task from processor j.
///
/// Defined only when `n[p][j] > 0`.  When the processor would become empty
/// the delta is exactly −μ_pj (its whole contribution disappears).
#[inline]
pub fn x_df_minus(mu: &AffinityMatrix, n: &StateMatrix, p: usize, j: usize) -> f64 {
    debug_assert!(n.get(p, j) > 0);
    let occ = n.col_sum(j) as f64;
    if occ <= 1.0 {
        return -mu.rate(p, j);
    }
    let xj = x_of_proc(mu, n, j);
    (xj - mu.rate(p, j)) / (occ - 1.0)
}

/// Incremental X(S) evaluator in a flat struct-of-arrays layout: the
/// rate matrix, the per-column numerators Σ_i μ_ij·N_ij, occupancies and
/// cached per-column throughputs X_j all live in contiguous `Vec<f64>`s
/// indexed by `j` — no nested indexing anywhere on the probe path, so
/// the row-delta loops auto-vectorize at large l.
///
/// * `x()` is O(l) (re-derived from the cached column sums, so it never
///   accumulates drift across moves),
/// * the GrIn move deltas (Eqs. 34/36) are **O(1)** per probe instead of
///   the O(k) column scan of [`x_df_plus`]/[`x_df_minus`], and
///   [`delta_plus_row`](Self::delta_plus_row) /
///   [`delta_minus_row`](Self::delta_minus_row) evaluate a whole row of
///   probes in one SIMD-friendly pass,
/// * applying a move updates two columns in O(1).
///
/// This is the hot path of GrIn's greedy loop (`benches/perf_hotpath.rs`
/// times it against the full evaluation) and of the leader's on-line
/// re-solves: one greedy step probes O(l²) moves per row, each now a
/// constant-time arithmetic expression.
#[derive(Debug, Clone)]
pub struct IncrementalX {
    /// Processor count l (columns).
    l: usize,
    /// Row-major k×l copy of μ in one contiguous allocation.
    rates: Vec<f64>,
    /// Per-column Σ_i μ_ij·N_ij.
    num: Vec<f64>,
    /// Per-column occupancy Σ_i N_ij (f64 to keep the probe arithmetic
    /// conversion-free; exact for any feasible population).
    occ: Vec<f64>,
    /// Cached per-column throughput X_j = num/occ (0 when empty).
    xj: Vec<f64>,
}

impl IncrementalX {
    /// Build the caches from a full state (O(k·l), once).
    pub fn new(mu: &AffinityMatrix, n: &StateMatrix) -> Self {
        debug_assert_eq!(mu.types(), n.types());
        debug_assert_eq!(mu.procs(), n.procs());
        let l = mu.procs();
        let rates = mu.data().to_vec();
        let mut num = vec![0.0f64; l];
        let mut occ = vec![0.0f64; l];
        for j in 0..l {
            for i in 0..mu.types() {
                let nij = n.get(i, j);
                num[j] += mu.rate(i, j) * nij as f64;
                occ[j] += nij as f64;
            }
        }
        let xj = (0..l)
            .map(|j| if occ[j] == 0.0 { 0.0 } else { num[j] / occ[j] })
            .collect();
        Self { l, rates, num, occ, xj }
    }

    /// Processor count l.
    #[inline]
    pub fn procs(&self) -> usize {
        self.l
    }

    /// Cached occupancy of column j (Σ_i N_ij) — exposed for the
    /// objective-scored evaluator ([`crate::model::objective::ObjectiveEval`]),
    /// which rides its power caches on these occupancies.
    #[inline]
    pub fn occupancy(&self, j: usize) -> f64 {
        self.occ[j]
    }

    /// Cached per-processor throughput X_j (Eq. 26/27).
    #[inline]
    pub fn x_of_proc(&self, j: usize) -> f64 {
        self.xj[j]
    }

    /// System throughput X_sys (Eq. 28), summed over the column caches
    /// in O(l).
    pub fn x(&self) -> f64 {
        self.xj.iter().sum()
    }

    /// Eq. 34 in O(1): ΔX of adding one p-type task to processor j.
    #[inline]
    pub fn delta_plus(&self, p: usize, j: usize) -> f64 {
        (self.rates[p * self.l + j] - self.xj[j]) / (self.occ[j] + 1.0)
    }

    /// Eq. 36 in O(1): ΔX of removing one p-type task from processor j.
    /// Defined only when the cell is occupied (caller-checked, as with
    /// [`x_df_minus`]).
    #[inline]
    pub fn delta_minus(&self, p: usize, j: usize) -> f64 {
        debug_assert!(self.occ[j] > 0.0);
        let rate = self.rates[p * self.l + j];
        if self.occ[j] <= 1.0 {
            return -rate;
        }
        (self.xj[j] - rate) / (self.occ[j] - 1.0)
    }

    /// Eq. 34 for the whole row p in one contiguous pass:
    /// `out[j] = ΔX of adding one p-type task to processor j`.  The loop
    /// reads three parallel `f64` slices and writes one — the
    /// SIMD-friendly layout the large-l GrIn probes want.
    #[inline]
    pub fn delta_plus_row(&self, p: usize, out: &mut [f64]) {
        debug_assert_eq!(out.len(), self.l);
        let row = &self.rates[p * self.l..(p + 1) * self.l];
        for (j, o) in out.iter_mut().enumerate() {
            *o = (row[j] - self.xj[j]) / (self.occ[j] + 1.0);
        }
    }

    /// Eq. 36 for the whole row p in one contiguous pass.  Entries for
    /// empty columns are filled with the occ≤1 closed form and are only
    /// meaningful where the caller knows `n[p][j] > 0` (as with
    /// [`delta_minus`](Self::delta_minus)).
    #[inline]
    pub fn delta_minus_row(&self, p: usize, out: &mut [f64]) {
        debug_assert_eq!(out.len(), self.l);
        let row = &self.rates[p * self.l..(p + 1) * self.l];
        for (j, o) in out.iter_mut().enumerate() {
            *o = if self.occ[j] <= 1.0 {
                -row[j]
            } else {
                (self.xj[j] - row[j]) / (self.occ[j] - 1.0)
            };
        }
    }

    /// Refresh the cached X_j for one column after a count change.
    #[inline]
    fn recache(&mut self, j: usize) {
        self.xj[j] = if self.occ[j] == 0.0 {
            // Cancel accumulated rounding dust on emptied columns so the
            // caches stay exact across arbitrarily long move sequences.
            self.num[j] = 0.0;
            0.0
        } else {
            self.num[j] / self.occ[j]
        };
    }

    /// Apply a task arrival at (p, j) to the caches.
    #[inline]
    pub fn apply_inc(&mut self, p: usize, j: usize) {
        self.num[j] += self.rates[p * self.l + j];
        self.occ[j] += 1.0;
        self.recache(j);
    }

    /// Apply a task departure from (p, j) to the caches.
    #[inline]
    pub fn apply_dec(&mut self, p: usize, j: usize) {
        debug_assert!(self.occ[j] > 0.0);
        self.num[j] -= self.rates[p * self.l + j];
        self.occ[j] -= 1.0;
        self.recache(j);
    }

    /// Apply a GrIn move (one p-type task from `from` to `to`).
    #[inline]
    pub fn apply_move(&mut self, p: usize, from: usize, to: usize) {
        self.apply_dec(p, from);
        self.apply_inc(p, to);
    }
}

/// Priority-weighted system throughput Xw(S) = Σ_j Σ_i w_ij·μ_ij·N_ij / Σ_i N_ij
/// — Eq. 28 with every cell's service rate discounted by a steering
/// weight (priority × estimate confidence, see
/// [`crate::policy::grin::priority_weights`]).  With all weights 1 this
/// is exactly [`x_of_state`].
pub fn weighted_x_of_state(mu: &AffinityMatrix, n: &StateMatrix, weights: &[f64]) -> Result<f64> {
    let scaled = mu.scaled(weights)?;
    Ok(x_of_state(&scaled, n))
}

/// [`IncrementalX`] over the priority-weighted objective Xw(S): every
/// cell's rate is w_ij·μ_ij, so a high-priority class's tasks claim
/// proportionally more of a processor's weighted throughput and a
/// low-confidence estimate discounts a class's claim on a fast device.
///
/// Structurally this *is* an `IncrementalX` whose rate matrix is the
/// element-wise product w ∘ μ — the GrIn greedy loop runs on it
/// unchanged ([`crate::policy::grin::solve_weighted`]), and every
/// complexity bound of the unweighted evaluator carries over.  With all
/// weights equal to 1 the caches are bit-identical to
/// [`IncrementalX::new`] on the raw matrix
/// (`tests/priority_e2e.rs` property-checks the equivalence).
#[derive(Debug, Clone)]
pub struct WeightedIncrementalX {
    inner: IncrementalX,
}

impl WeightedIncrementalX {
    /// Build the weighted caches; `weights` is row-major k×l (or l
    /// per-processor factors), every factor finite and > 0.
    pub fn new(mu: &AffinityMatrix, n: &StateMatrix, weights: &[f64]) -> Result<Self> {
        let scaled = mu.scaled(weights)?;
        Ok(Self { inner: IncrementalX::new(&scaled, n) })
    }

    /// Processor count l.
    #[inline]
    pub fn procs(&self) -> usize {
        self.inner.procs()
    }

    /// Weighted system throughput Xw(S), summed over the column caches.
    pub fn x(&self) -> f64 {
        self.inner.x()
    }

    /// Weighted Eq. 34 in O(1): ΔXw of adding one p-type task to j.
    #[inline]
    pub fn delta_plus(&self, p: usize, j: usize) -> f64 {
        self.inner.delta_plus(p, j)
    }

    /// Weighted Eq. 36 in O(1): ΔXw of removing one p-type task from j
    /// (defined only when the cell is occupied, as with
    /// [`IncrementalX::delta_minus`]).
    #[inline]
    pub fn delta_minus(&self, p: usize, j: usize) -> f64 {
        self.inner.delta_minus(p, j)
    }

    /// Weighted Eq. 34 for the whole row p in one contiguous pass.
    #[inline]
    pub fn delta_plus_row(&self, p: usize, out: &mut [f64]) {
        self.inner.delta_plus_row(p, out);
    }

    /// Weighted Eq. 36 for the whole row p in one contiguous pass.
    #[inline]
    pub fn delta_minus_row(&self, p: usize, out: &mut [f64]) {
        self.inner.delta_minus_row(p, out);
    }

    /// Apply a GrIn move (one p-type task from `from` to `to`).
    #[inline]
    pub fn apply_move(&mut self, p: usize, from: usize, to: usize) {
        self.inner.apply_move(p, from, to);
    }
}

/// Closed-form maximum throughput for a classified two-type regime
/// (Table 1 rows; Eqs. 16–18 and cases a.1–a.3).
pub fn x_max_theoretical(
    mu: &AffinityMatrix,
    regime: Regime,
    n1: u32,
    n2: u32,
) -> f64 {
    let (m11, m12) = (mu.rate(0, 0), mu.rate(0, 1));
    let (m21, m22) = (mu.rate(1, 0), mu.rate(1, 1));
    let n = (n1 + n2) as f64;
    match regime {
        // a.1 homogeneous & a.2 big.LITTLE: X = μ11 + μ22 whenever both
        // queues stay non-empty.
        Regime::Homogeneous | Regime::BigLittleLike => m11 + m22,
        // a.3 symmetric and b.3 general-symmetric: S_max = (N1, N2).
        Regime::Symmetric | Regime::GeneralSymmetric => m11 + m22,
        // b.1 (Eq. 16): S_max = (1, N2).
        Regime::P1Biased => {
            (n1 as f64 - 1.0) / (n - 1.0) * m12 + n2 as f64 / (n - 1.0) * m22 + m11
        }
        // b.2 (Eq. 17): S_max = (N1, 1).
        Regime::P2Biased => {
            (n2 as f64 - 1.0) / (n - 1.0) * m21 + n1 as f64 / (n - 1.0) * m11 + m22
        }
    }
}

/// The optimal target state S_max for a classified regime (Table 1).
///
/// For the non-affinity regimes any interior state is optimal; we return
/// the balanced Best-Fit-style state as a canonical representative.
pub fn s_max(regime: Regime, n1: u32, n2: u32) -> (u32, u32) {
    match regime {
        Regime::Homogeneous | Regime::BigLittleLike => {
            // Any -N1 < N22-N11 < N2 works; split each type evenly.
            (n1 / 2 + n1 % 2, n2 / 2 + n2 % 2)
        }
        Regime::Symmetric | Regime::GeneralSymmetric => (n1, n2),
        Regime::P1Biased => (1.min(n1), n2),
        Regime::P2Biased => (n1, 1.min(n2)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn paper_mu() -> AffinityMatrix {
        // §5 simulation matrix, P1-biased.
        AffinityMatrix::two_type(20.0, 15.0, 3.0, 8.0).unwrap()
    }

    #[test]
    fn empty_processor_contributes_zero() {
        let mu = paper_mu();
        let s = StateMatrix::new(2, 2, vec![0, 5, 0, 5]).unwrap();
        assert_eq!(x_of_proc(&mu, &s, 0), 0.0);
        assert!(x_of_proc(&mu, &s, 1) > 0.0);
    }

    #[test]
    fn eq4_matches_manual_computation() {
        let mu = paper_mu();
        // N1 = 10, N2 = 10, S = (1, 10): P1 holds {1×t1}, P2 holds {9×t1, 10×t2}.
        let x = x_two_type(&mu, 1, 10, 10, 10).unwrap();
        let manual = 20.0 + (15.0 * 9.0 + 8.0 * 10.0) / 19.0;
        assert!((x - manual).abs() < 1e-12);
    }

    #[test]
    fn eq16_matches_x_of_state_at_smax() {
        let mu = paper_mu();
        for (n1, n2) in [(2u32, 18u32), (10, 10), (18, 2), (5, 15)] {
            let theory = x_max_theoretical(&mu, Regime::P1Biased, n1, n2);
            let x = x_two_type(&mu, 1, n2, n1, n2).unwrap();
            assert!(
                (theory - x).abs() < 1e-12,
                "N1={n1} N2={n2}: theory {theory} vs eq4 {x}"
            );
        }
    }

    #[test]
    fn eq17_matches_x_of_state_at_smax() {
        // P2-biased: Table-3 derived matrix (quicksort-1000 + NN-2000).
        let mu = AffinityMatrix::two_type(253.0, 0.911, 587.0, 2398.0).unwrap();
        for (n1, n2) in [(4u32, 16u32), (10, 10), (16, 4)] {
            let theory = x_max_theoretical(&mu, Regime::P2Biased, n1, n2);
            let x = x_two_type(&mu, n1, 1, n1, n2).unwrap();
            assert!((theory - x).abs() < 1e-9);
        }
    }

    #[test]
    fn gradient_matches_finite_difference() {
        let mu = paper_mu();
        let (n1, n2) = (12.0, 8.0);
        let (n11, n22) = (4.0, 5.0);
        let (g11, g22) = grad_two_type(&mu, n11, n22, n1, n2);
        let h = 1e-6;
        let x = |a: f64, b: f64| {
            // Relaxed Eq. 4 evaluated on reals.
            let d1 = a + n2 - b;
            let d2 = b + n1 - a;
            (20.0 * a + 3.0 * (n2 - b)) / d1 + (8.0 * b + 15.0 * (n1 - a)) / d2
        };
        let fd11 = (x(n11 + h, n22) - x(n11 - h, n22)) / (2.0 * h);
        let fd22 = (x(n11, n22 + h) - x(n11, n22 - h)) / (2.0 * h);
        assert!((g11 - fd11).abs() < 1e-5, "{g11} vs {fd11}");
        assert!((g22 - fd22).abs() < 1e-5, "{g22} vs {fd22}");
    }

    #[test]
    fn move_deltas_match_recomputation() {
        let mu = AffinityMatrix::from_rows(&[
            vec![10.0, 2.0, 4.0],
            vec![1.0, 8.0, 3.0],
            vec![5.0, 5.0, 9.0],
        ])
        .unwrap();
        let s = StateMatrix::new(3, 3, vec![3, 1, 0, 2, 4, 1, 0, 2, 5]).unwrap();
        for p in 0..3 {
            for j in 0..3 {
                // X_df+ vs brute-force re-evaluation.
                let mut s2 = s.clone();
                s2.inc(p, j);
                let want = x_of_proc(&mu, &s2, j) - x_of_proc(&mu, &s, j);
                let got = x_df_plus(&mu, &s, p, j);
                assert!((got - want).abs() < 1e-12, "plus p={p} j={j}");
                // X_df- where defined.
                if s.get(p, j) > 0 {
                    let mut s3 = s.clone();
                    s3.dec(p, j).unwrap();
                    let want = x_of_proc(&mu, &s3, j) - x_of_proc(&mu, &s, j);
                    let got = x_df_minus(&mu, &s, p, j);
                    assert!((got - want).abs() < 1e-12, "minus p={p} j={j}");
                }
            }
        }
    }

    #[test]
    fn incremental_matches_full_evaluation_across_moves() {
        let mu = AffinityMatrix::from_rows(&[
            vec![10.0, 2.0, 4.0],
            vec![1.0, 8.0, 3.0],
            vec![5.0, 5.0, 9.0],
        ])
        .unwrap();
        let mut s = StateMatrix::new(3, 3, vec![3, 1, 0, 2, 4, 1, 0, 2, 5]).unwrap();
        let mut inc = IncrementalX::new(&mu, &s);
        assert!((inc.x() - x_of_state(&mu, &s)).abs() < 1e-12);
        // O(1) deltas equal the O(k) reference deltas on every cell, and
        // the row passes agree entry-for-entry with the scalar probes.
        let mut dplus = vec![0.0f64; 3];
        let mut dminus = vec![0.0f64; 3];
        for p in 0..3 {
            inc.delta_plus_row(p, &mut dplus);
            inc.delta_minus_row(p, &mut dminus);
            for j in 0..3 {
                let want = x_df_plus(&mu, &s, p, j);
                assert!((inc.delta_plus(p, j) - want).abs() < 1e-12);
                assert_eq!(dplus[j].to_bits(), inc.delta_plus(p, j).to_bits());
                if s.get(p, j) > 0 {
                    let want = x_df_minus(&mu, &s, p, j);
                    assert!((inc.delta_minus(p, j) - want).abs() < 1e-12);
                    assert_eq!(dminus[j].to_bits(), inc.delta_minus(p, j).to_bits());
                }
            }
        }
        // A deterministic move walk: caches track the full recomputation.
        let moves = [(0usize, 0usize, 1usize), (1, 1, 2), (2, 2, 0), (0, 0, 2), (1, 2, 0)];
        for &(p, from, to) in &moves {
            if s.get(p, from) == 0 {
                continue;
            }
            let predicted = inc.delta_minus(p, from) + inc.delta_plus(p, to);
            let before = inc.x();
            s.move_task(p, from, to).unwrap();
            inc.apply_move(p, from, to);
            assert!((inc.x() - x_of_state(&mu, &s)).abs() < 1e-9);
            assert!((inc.x() - before - predicted).abs() < 1e-9);
        }
    }

    #[test]
    fn incremental_handles_emptying_and_refilling_columns() {
        let mu = AffinityMatrix::two_type(20.0, 15.0, 3.0, 8.0).unwrap();
        let mut s = StateMatrix::new(2, 2, vec![1, 0, 0, 1]).unwrap();
        let mut inc = IncrementalX::new(&mu, &s);
        assert!((inc.x() - 28.0).abs() < 1e-12); // 20 + 8
        // Empty column 0 entirely.
        s.move_task(0, 0, 1).unwrap();
        inc.apply_move(0, 0, 1);
        assert_eq!(inc.x_of_proc(0), 0.0);
        assert!((inc.x() - x_of_state(&mu, &s)).abs() < 1e-12);
        // Refill it.
        s.move_task(1, 1, 0).unwrap();
        inc.apply_move(1, 1, 0);
        assert!((inc.x() - x_of_state(&mu, &s)).abs() < 1e-12);
    }

    #[test]
    fn weighted_incremental_with_unit_weights_matches_unweighted() {
        let mu = AffinityMatrix::from_rows(&[
            vec![10.0, 2.0, 4.0],
            vec![1.0, 8.0, 3.0],
            vec![5.0, 5.0, 9.0],
        ])
        .unwrap();
        let s = StateMatrix::new(3, 3, vec![3, 1, 0, 2, 4, 1, 0, 2, 5]).unwrap();
        let ones = vec![1.0; 9];
        let w = WeightedIncrementalX::new(&mu, &s, &ones).unwrap();
        let inc = IncrementalX::new(&mu, &s);
        assert_eq!(w.x().to_bits(), inc.x().to_bits());
        for p in 0..3 {
            for j in 0..3 {
                assert_eq!(w.delta_plus(p, j).to_bits(), inc.delta_plus(p, j).to_bits());
                if s.get(p, j) > 0 {
                    assert_eq!(w.delta_minus(p, j).to_bits(), inc.delta_minus(p, j).to_bits());
                }
            }
        }
        assert!((weighted_x_of_state(&mu, &s, &ones).unwrap() - x_of_state(&mu, &s)).abs()
            < 1e-12);
    }

    #[test]
    fn weighted_incremental_tracks_scaled_matrix() {
        // Xw on μ with weights w must equal X on the pre-scaled matrix
        // w ∘ μ, across moves.
        let mu = AffinityMatrix::two_type(20.0, 15.0, 3.0, 8.0).unwrap();
        let weights = vec![2.0, 2.0, 0.5, 0.5]; // class 0 twice the claim
        let scaled = mu.scaled(&weights).unwrap();
        let mut s = StateMatrix::new(2, 2, vec![2, 1, 1, 3]).unwrap();
        let mut w = WeightedIncrementalX::new(&mu, &s, &weights).unwrap();
        assert!((w.x() - x_of_state(&scaled, &s)).abs() < 1e-12);
        let mut dplus = vec![0.0f64; 2];
        w.delta_plus_row(0, &mut dplus);
        for j in 0..2 {
            assert!((dplus[j] - x_df_plus(&scaled, &s, 0, j)).abs() < 1e-12);
        }
        s.move_task(1, 1, 0).unwrap();
        w.apply_move(1, 1, 0);
        assert!((w.x() - x_of_state(&scaled, &s)).abs() < 1e-12);
        assert!(
            (weighted_x_of_state(&mu, &s, &weights).unwrap() - x_of_state(&scaled, &s)).abs()
                < 1e-12
        );
        // Bad weights are rejected, not silently clamped.
        assert!(WeightedIncrementalX::new(&mu, &s, &[1.0, -1.0, 1.0, 1.0]).is_err());
        assert!(WeightedIncrementalX::new(&mu, &s, &[1.0; 3]).is_err());
    }

    #[test]
    fn smax_targets_match_table1() {
        assert_eq!(s_max(Regime::GeneralSymmetric, 7, 13), (7, 13));
        assert_eq!(s_max(Regime::P1Biased, 7, 13), (1, 13));
        assert_eq!(s_max(Regime::P2Biased, 7, 13), (7, 1));
    }
}
