//! System state matrix N (Def. 5) — how many i-type tasks sit on each
//! j-type processor — with the row-sum invariant of Eq. 3 / Eq. 29.

// srclint: allow-file(index-reachable) — occupancy grids are k by l by construction

use crate::error::{Error, Result};

/// Dense k×l non-negative integer matrix; `n[i][j]` = number of i-type
/// tasks on processor j.  Row sums are the per-type populations `N_i`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StateMatrix {
    k: usize,
    l: usize,
    n: Vec<u32>,
}

impl StateMatrix {
    /// All-zero state.
    pub fn zeros(k: usize, l: usize) -> Self {
        Self { k, l, n: vec![0; k * l] }
    }

    /// Build from row-major counts.
    pub fn new(k: usize, l: usize, n: Vec<u32>) -> Result<Self> {
        if k == 0 || l == 0 || n.len() != k * l {
            return Err(Error::Shape(format!(
                "state matrix {}x{} with {} entries",
                k,
                l,
                n.len()
            )));
        }
        Ok(Self { k, l, n })
    }

    /// The paper's two-type shorthand S = (N11, N22) with populations
    /// (N1, N2): N12 = N1 − N11 and N21 = N2 − N22 (Eq. 3).
    pub fn from_two_type(n11: u32, n22: u32, n1: u32, n2: u32) -> Result<Self> {
        if n11 > n1 || n22 > n2 {
            return Err(Error::Shape(format!(
                "S=({n11},{n22}) outside populations ({n1},{n2})"
            )));
        }
        Self::new(2, 2, vec![n11, n1 - n11, n2 - n22, n22])
    }

    /// Task-type count (rows).
    #[inline]
    pub fn types(&self) -> usize {
        self.k
    }

    /// Processor-type count (columns).
    #[inline]
    pub fn procs(&self) -> usize {
        self.l
    }

    /// Count of i-type tasks on processor j.
    #[inline]
    pub fn get(&self, i: usize, j: usize) -> u32 {
        debug_assert!(i < self.k && j < self.l);
        self.n[i * self.l + j]
    }

    /// Mutable access.
    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: u32) {
        debug_assert!(i < self.k && j < self.l);
        self.n[i * self.l + j] = v;
    }

    /// Increment (task arrival at processor j).
    #[inline]
    pub fn inc(&mut self, i: usize, j: usize) {
        self.n[i * self.l + j] += 1;
    }

    /// Decrement (task departure); errors if the cell is empty.
    pub fn dec(&mut self, i: usize, j: usize) -> Result<()> {
        let c = &mut self.n[i * self.l + j];
        if *c == 0 {
            return Err(Error::Shape(format!(
                "decrement of empty cell ({i},{j})"
            )));
        }
        *c -= 1;
        Ok(())
    }

    /// Move one i-type task from processor `from` to processor `to`
    /// (a GrIn move; preserves row sums by construction).
    pub fn move_task(&mut self, i: usize, from: usize, to: usize) -> Result<()> {
        self.dec(i, from)?;
        self.inc(i, to);
        Ok(())
    }

    /// Population of task type i (row sum, the constraint of Eq. 29).
    pub fn row_sum(&self, i: usize) -> u32 {
        self.row(i).iter().sum()
    }

    /// Occupancy of processor j (column sum; the PS denominator, Eq. 25).
    pub fn col_sum(&self, j: usize) -> u32 {
        (0..self.k).map(|i| self.get(i, j)).sum()
    }

    /// Total tasks in the system (= N in the closed network).
    pub fn total(&self) -> u32 {
        self.n.iter().sum()
    }

    /// Row slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[u32] {
        &self.n[i * self.l..(i + 1) * self.l]
    }

    /// Raw row-major data.
    #[inline]
    pub fn data(&self) -> &[u32] {
        &self.n
    }

    /// Check `row_sum(i) == populations[i]` for all rows (Eq. 29).
    pub fn check_populations(&self, populations: &[u32]) -> Result<()> {
        if populations.len() != self.k {
            return Err(Error::Shape("population vector length".into()));
        }
        for (i, &ni) in populations.iter().enumerate() {
            let got = self.row_sum(i);
            if got != ni {
                return Err(Error::Shape(format!(
                    "row {i} sums to {got}, expected {ni}"
                )));
            }
        }
        Ok(())
    }

    /// f32 copy padded to (k_pad, l_pad), row-major — the layout the
    /// `throughput_eval` PJRT artifact expects.
    pub fn to_padded_f32(&self, k_pad: usize, l_pad: usize) -> Result<Vec<f32>> {
        if k_pad < self.k || l_pad < self.l {
            return Err(Error::Shape(format!(
                "pad ({k_pad},{l_pad}) smaller than ({},{})",
                self.k, self.l
            )));
        }
        let mut out = vec![0f32; k_pad * l_pad];
        for i in 0..self.k {
            for j in 0..self.l {
                out[i * l_pad + j] = self.get(i, j) as f32;
            }
        }
        Ok(out)
    }
}

impl std::fmt::Display for StateMatrix {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        for i in 0..self.k {
            write!(f, "[")?;
            for j in 0..self.l {
                if j > 0 {
                    write!(f, " ")?;
                }
                write!(f, "{}", self.get(i, j))?;
            }
            writeln!(f, "]")?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn two_type_shorthand_matches_eq3() {
        let s = StateMatrix::from_two_type(1, 18, 2, 18).unwrap();
        assert_eq!(s.get(0, 0), 1); // N11
        assert_eq!(s.get(0, 1), 1); // N12 = N1 - N11
        assert_eq!(s.get(1, 0), 0); // N21 = N2 - N22
        assert_eq!(s.get(1, 1), 18); // N22
        assert_eq!(s.total(), 20);
        assert!(StateMatrix::from_two_type(3, 0, 2, 5).is_err());
    }

    #[test]
    fn sums_and_moves() {
        let mut s = StateMatrix::new(2, 3, vec![1, 2, 3, 4, 0, 6]).unwrap();
        assert_eq!(s.row_sum(0), 6);
        assert_eq!(s.col_sum(0), 5);
        assert_eq!(s.col_sum(1), 2);
        s.move_task(0, 2, 1).unwrap();
        assert_eq!(s.get(0, 2), 2);
        assert_eq!(s.get(0, 1), 3);
        assert_eq!(s.row_sum(0), 6); // moves preserve populations
        assert!(s.move_task(1, 1, 0).is_err()); // empty cell
    }

    #[test]
    fn population_check() {
        let s = StateMatrix::new(2, 2, vec![1, 1, 0, 18]).unwrap();
        assert!(s.check_populations(&[2, 18]).is_ok());
        assert!(s.check_populations(&[3, 17]).is_err());
        assert!(s.check_populations(&[2]).is_err());
    }

    #[test]
    fn padding_layout() {
        let s = StateMatrix::new(2, 2, vec![1, 2, 3, 4]).unwrap();
        let p = s.to_padded_f32(3, 4).unwrap();
        assert_eq!(p.len(), 12);
        assert_eq!(p[0], 1.0);
        assert_eq!(p[1], 2.0);
        assert_eq!(p[4], 3.0);
        assert_eq!(p[5], 4.0);
        assert_eq!(p[2], 0.0);
        assert!(s.to_padded_f32(1, 4).is_err());
    }
}
