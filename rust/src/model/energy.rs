//! Energy and Energy-Delay-Product model (§3.4).
//!
//! Expected energy per task (Eq. 19), delay per task via Little's Law
//! (Eq. 20), EDP (Eq. 21), the Scenario-1/2 closed forms (Eqs. 22–23) and
//! the Lemma-7 α-bounds.

// srclint: allow-file(index-reachable) — dense k by l parameter matrices validated by the platform check at construction

use super::affinity::AffinityMatrix;
use super::state::StateMatrix;
use super::throughput::x_of_state;
use crate::error::{Error, Result};

/// The two analyzed power scenarios (§3.2) plus the general exponent.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PowerScenario {
    /// Scenario 1: 𝒫_ij = k (α = 0) — the strong/weak affinity boundary.
    Constant,
    /// Scenario 2: 𝒫_ij = k·μ_ij (α = 1) — power ∝ speed.
    Proportional,
    /// General regime: 𝒫_ij = k·μ_ij^α, α ≤ 1 (Lemma 7 bounds apply).
    Exponent(f64),
}

impl PowerScenario {
    /// The α exponent of this scenario.
    pub fn alpha(self) -> f64 {
        match self {
            PowerScenario::Constant => 0.0,
            PowerScenario::Proportional => 1.0,
            PowerScenario::Exponent(a) => a,
        }
    }

    /// Parse a CLI/config name: `constant`, `proportional`, or
    /// `exponent:<alpha>` (e.g. `exponent:0.5`); α ≤ 1 enforced by the
    /// consumer ([`EnergyModel::new`] /
    /// [`crate::model::objective::PowerProfile::validate`]).
    pub fn parse(name: &str) -> Result<Self> {
        match name {
            "constant" => Ok(PowerScenario::Constant),
            "proportional" => Ok(PowerScenario::Proportional),
            other => match other.strip_prefix("exponent:") {
                Some(a) => a
                    .parse::<f64>()
                    .map(PowerScenario::Exponent)
                    .map_err(|_| Error::Parse(format!("bad power exponent '{a}'"))),
                None => Err(Error::Parse(format!(
                    "unknown power scenario '{other}' \
                     (constant|proportional|exponent:<alpha>)"
                ))),
            },
        }
    }

    /// Canonical name (the exponent's α is not encoded).
    pub fn name(self) -> &'static str {
        match self {
            PowerScenario::Constant => "constant",
            PowerScenario::Proportional => "proportional",
            PowerScenario::Exponent(_) => "exponent",
        }
    }
}

/// Energy model bound to an affinity matrix: 𝒫_ij = coeff·μ_ij^α.
#[derive(Debug, Clone)]
pub struct EnergyModel {
    power: Vec<f64>,
    l: usize,
    coeff: f64,
    scenario: PowerScenario,
}

impl EnergyModel {
    /// Build the power matrix for the scenario.
    pub fn new(mu: &AffinityMatrix, coeff: f64, scenario: PowerScenario) -> Result<Self> {
        if coeff <= 0.0 || !coeff.is_finite() {
            return Err(Error::Config(format!("power coefficient {coeff}")));
        }
        let a = scenario.alpha();
        if a > 1.0 {
            return Err(Error::Config(format!(
                "α = {a} > 1 is outside the paper's power model"
            )));
        }
        Ok(Self {
            power: mu.power_matrix(coeff, a),
            l: mu.procs(),
            coeff,
            scenario,
        })
    }

    /// 𝒫_ij.
    #[inline]
    pub fn power(&self, i: usize, j: usize) -> f64 {
        self.power[i * self.l + j]
    }

    /// Expected energy per task (Eq. 19) at a given state.
    ///
    /// E[ℰ] = (1/X) Σ_j Σ_i (N_ij / occ_j) · 𝒫_ij, with empty processors
    /// contributing nothing (they draw no dynamic task power).
    pub fn energy_per_task(&self, mu: &AffinityMatrix, s: &StateMatrix) -> f64 {
        let x = x_of_state(mu, s);
        if x <= 0.0 {
            return f64::INFINITY;
        }
        let mut acc = 0.0;
        for j in 0..s.procs() {
            let occ = s.col_sum(j);
            if occ == 0 {
                continue;
            }
            for i in 0..s.types() {
                acc += s.get(i, j) as f64 / occ as f64 * self.power(i, j);
            }
        }
        acc / x
    }

    /// Delay per task via Little's Law (Eq. 20): E[T] = N / X.
    pub fn delay_per_task(&self, mu: &AffinityMatrix, s: &StateMatrix) -> f64 {
        let x = x_of_state(mu, s);
        if x <= 0.0 {
            return f64::INFINITY;
        }
        s.total() as f64 / x
    }

    /// EDP (Eq. 21) = E[ℰ]·N/X.
    pub fn edp(&self, mu: &AffinityMatrix, s: &StateMatrix) -> f64 {
        self.energy_per_task(mu, s) * self.delay_per_task(mu, s)
    }

    /// Scenario closed forms (Eqs. 22–23) for a state with every
    /// processor occupied; returns `(E[ℰ], EDP)` or None when the closed
    /// form does not apply — general α, or a state violating the
    /// Eqs. 22–23 precondition that all processors are busy (an empty
    /// column draws no task power, so the l·k/X sum overcounts it).
    pub fn closed_form(&self, x: f64, s: &StateMatrix) -> Option<(f64, f64)> {
        if (0..s.procs()).any(|j| s.col_sum(j) == 0) {
            return None;
        }
        let n_total = s.total();
        match self.scenario {
            PowerScenario::Constant => {
                let e = s.procs() as f64 * self.coeff / x;
                Some((e, e * n_total as f64 / x))
            }
            PowerScenario::Proportional => {
                let e = self.coeff;
                Some((e, e * n_total as f64 / x))
            }
            PowerScenario::Exponent(_) => None,
        }
    }

    /// Lemma-7 bounds on E[ℰ(α)] given throughput X: returns
    /// `(lower, upper)`; `upper` may be +∞ only if X = 0.
    pub fn lemma7_energy_bounds(&self, x: f64, n_procs_busy: usize) -> (f64, f64) {
        let b = n_procs_busy as f64 * self.coeff / x; // Σ_busy k/X
        match self.scenario.alpha() {
            a if a <= 0.0 => (0.0, b),
            _ => (b, self.coeff),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::affinity::Regime;
    use crate::model::throughput::{x_max_theoretical, x_of_state};

    fn setup() -> (AffinityMatrix, StateMatrix) {
        let mu = AffinityMatrix::two_type(20.0, 15.0, 3.0, 8.0).unwrap();
        // S_max for P1-biased, N1 = N2 = 10.
        let s = StateMatrix::from_two_type(1, 10, 10, 10).unwrap();
        (mu, s)
    }

    #[test]
    fn proportional_power_energy_is_constant_k() {
        // Eq. 23: E[ℰ] = k under 𝒫 = k·μ (both processors busy).
        let (mu, s) = setup();
        let em = EnergyModel::new(&mu, 1.0, PowerScenario::Proportional).unwrap();
        let e = em.energy_per_task(&mu, &s);
        assert!((e - 1.0).abs() < 1e-12, "E[ℰ] = {e}");
    }

    #[test]
    fn constant_power_energy_is_2k_over_x() {
        // Eq. 22: E[ℰ] = 2k/X.
        let (mu, s) = setup();
        let em = EnergyModel::new(&mu, 3.0, PowerScenario::Constant).unwrap();
        let x = x_of_state(&mu, &s);
        let e = em.energy_per_task(&mu, &s);
        assert!((e - 6.0 / x).abs() < 1e-12);
        let (ec, edpc) = em.closed_form(x, &s).unwrap();
        assert!((e - ec).abs() < 1e-12);
        assert!((em.edp(&mu, &s) - edpc).abs() < 1e-12);
    }

    #[test]
    fn max_throughput_minimizes_edp_scenarios() {
        // Lemma 6: at S_max both energy and EDP are minimal among states.
        let mu = AffinityMatrix::two_type(20.0, 15.0, 3.0, 8.0).unwrap();
        let em = EnergyModel::new(&mu, 1.0, PowerScenario::Constant).unwrap();
        let (n1, n2) = (10u32, 10u32);
        let s_opt = StateMatrix::from_two_type(1, n2, n1, n2).unwrap();
        let best_edp = em.edp(&mu, &s_opt);
        for n11 in 0..=n1 {
            for n22 in 0..=n2 {
                let s = StateMatrix::from_two_type(n11, n22, n1, n2).unwrap();
                if x_of_state(&mu, &s) <= 0.0 {
                    continue;
                }
                assert!(
                    em.edp(&mu, &s) >= best_edp - 1e-9,
                    "state ({n11},{n22}) beats S_max in EDP"
                );
            }
        }
        // And the optimum matches the Eq. 16 throughput.
        let x = x_of_state(&mu, &s_opt);
        let want = x_max_theoretical(&mu, Regime::P1Biased, n1, n2);
        assert!((x - want).abs() < 1e-12);
    }

    #[test]
    fn lemma7_bounds_hold_for_intermediate_alpha() {
        let (mu, s) = setup();
        let x = x_of_state(&mu, &s);
        for &alpha in &[-1.0, -0.5, 0.25, 0.5, 0.9] {
            let em = EnergyModel::new(&mu, 1.0, PowerScenario::Exponent(alpha)).unwrap();
            let e = em.energy_per_task(&mu, &s);
            let (lo, hi) = em.lemma7_energy_bounds(x, 2);
            assert!(e >= lo - 1e-9 && e <= hi + 1e-9, "α={alpha}: {lo} ≤ {e} ≤ {hi}");
        }
    }

    #[test]
    fn closed_form_rejects_states_with_an_empty_column() {
        // Regression: Eqs. 22–23 assume every processor is busy.  A state
        // that drains a column used to get Some(2k/X) back even though
        // the true Eq. 19 energy only counts the busy processor.
        let (mu, _) = setup();
        let em = EnergyModel::new(&mu, 3.0, PowerScenario::Constant).unwrap();
        // All 20 programs on processor 0; column 1 empty.
        let s = StateMatrix::from_two_type(10, 0, 10, 10).unwrap();
        assert_eq!(s.col_sum(1), 0);
        let x = x_of_state(&mu, &s);
        assert!(em.closed_form(x, &s).is_none());
        // The closed form still matches Eq. 19 exactly when the
        // precondition holds (both columns busy).
        let s_busy = StateMatrix::from_two_type(1, 10, 10, 10).unwrap();
        let xb = x_of_state(&mu, &s_busy);
        let (ec, _) = em.closed_form(xb, &s_busy).unwrap();
        assert!((em.energy_per_task(&mu, &s_busy) - ec).abs() < 1e-12);
    }

    #[test]
    fn power_scenario_parses_cli_names() {
        assert_eq!(PowerScenario::parse("constant").unwrap(), PowerScenario::Constant);
        assert_eq!(
            PowerScenario::parse("proportional").unwrap(),
            PowerScenario::Proportional
        );
        assert_eq!(
            PowerScenario::parse("exponent:0.5").unwrap(),
            PowerScenario::Exponent(0.5)
        );
        assert!(PowerScenario::parse("exponent:x").is_err());
        assert!(PowerScenario::parse("quadratic").is_err());
        for s in [PowerScenario::Constant, PowerScenario::Proportional] {
            assert_eq!(PowerScenario::parse(s.name()).unwrap(), s);
        }
        assert_eq!(PowerScenario::Exponent(0.5).name(), "exponent");
    }

    #[test]
    fn rejects_invalid_config() {
        let (mu, _) = setup();
        assert!(EnergyModel::new(&mu, 0.0, PowerScenario::Constant).is_err());
        assert!(EnergyModel::new(&mu, 1.0, PowerScenario::Exponent(1.5)).is_err());
    }

    #[test]
    fn empty_system_has_infinite_energy_and_delay() {
        let mu = AffinityMatrix::two_type(20.0, 15.0, 3.0, 8.0).unwrap();
        let s = StateMatrix::zeros(2, 2);
        let em = EnergyModel::new(&mu, 1.0, PowerScenario::Constant).unwrap();
        assert!(em.energy_per_task(&mu, &s).is_infinite());
        assert!(em.delay_per_task(&mu, &s).is_infinite());
    }
}
