//! The scheduling objective axis: what a solve optimizes (§3.4 × §4.2).
//!
//! PRs 1–5 optimized throughput only, leaving the §3.4 energy model
//! ([`crate::model::energy`]) dormant.  This module turns Eq. 19
//! (energy per task) and Eq. 21 (EDP) into first-class solve objectives
//! behind one enum, consumed by GrIn's greedy loop through
//! [`ObjectiveEval`] — the objective-scored sibling of
//! [`IncrementalX`]:
//!
//! * [`Objective::Throughput`] — maximize X_sys (Eq. 28), the original
//!   axis; bit-identical to the pre-objective solve paths.
//! * [`Objective::EnergyPerTask`] — minimize E[ℰ] (Eq. 19).
//! * [`Objective::Edp`] — minimize E[ℰ]·N/X (Eq. 21).
//! * [`Objective::ThroughputPerWatt`] — maximize X/𝒫_sys subject to
//!   X ≥ `min_x_frac`·X*, the constrained perf-per-watt mode (the
//!   energy-aware-under-throughput-constraint formulation).
//!
//! [`ObjectiveEval`] keeps per-column power numerators Σ_i N_ij·𝒫_ij
//! alongside the [`IncrementalX`] throughput caches, so a GrIn move
//! probe stays O(1) (only the two touched columns change) and a full
//! objective evaluation is O(l) — the same bounds as the throughput
//! greedy loop.
//!
//! [`PowerProfile`] bundles the §3.2 power model (𝒫_ij = coeff·μ_ij^α)
//! with an *idle-power floor*: an empty device still draws
//! `idle_power`, so energy per task is not trivially minimized by
//! draining devices (with zero idle power, parking every task on the
//! single most efficient cell minimizes Eq. 19 outright at a huge
//! throughput cost).

// srclint: allow-file(index-reachable) — dense k by l parameter matrices validated by the platform check at construction

use super::affinity::AffinityMatrix;
use super::energy::PowerScenario;
use super::state::StateMatrix;
use super::throughput::IncrementalX;
use crate::error::{Error, Result};

/// Default throughput floor for [`Objective::ThroughputPerWatt`] when
/// the CLI spelling `tpw` carries no explicit fraction.
pub const DEFAULT_MIN_X_FRAC: f64 = 0.9;

/// What a solve optimizes.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Objective {
    /// Maximize system throughput X (Eq. 28) — the default.
    Throughput,
    /// Minimize expected energy per task E[ℰ] (Eq. 19).
    EnergyPerTask,
    /// Minimize the energy-delay product E[ℰ]·N/X (Eq. 21).
    Edp,
    /// Maximize X/𝒫_sys subject to X ≥ `min_x_frac`·X*, where X* is the
    /// unconstrained throughput optimum.
    ThroughputPerWatt {
        /// Throughput floor as a fraction of X*, in (0, 1].
        min_x_frac: f64,
    },
}

impl Default for Objective {
    fn default() -> Self {
        Objective::Throughput
    }
}

impl Objective {
    /// Parse a CLI/config name: `throughput`, `energy`, `edp`, `tpw`
    /// or `tpw:<frac>` (e.g. `tpw:0.85`).
    pub fn parse(name: &str) -> Result<Self> {
        let lower = name.to_ascii_lowercase();
        let (head, frac) = match lower.split_once(':') {
            Some((h, f)) => (h, Some(f)),
            None => (lower.as_str(), None),
        };
        let obj = match head {
            "throughput" | "x" => Objective::Throughput,
            "energy" | "energy_per_task" => Objective::EnergyPerTask,
            "edp" => Objective::Edp,
            "tpw" | "throughput_per_watt" => {
                let min_x_frac = match frac {
                    Some(s) => s.parse::<f64>().map_err(|_| {
                        Error::Parse(format!("bad min-X fraction '{s}' in objective '{name}'"))
                    })?,
                    None => DEFAULT_MIN_X_FRAC,
                };
                Objective::ThroughputPerWatt { min_x_frac }
            }
            other => {
                return Err(Error::Parse(format!(
                    "unknown objective '{other}' (throughput|energy|edp|tpw[:frac])"
                )))
            }
        };
        if frac.is_some() && !matches!(obj, Objective::ThroughputPerWatt { .. }) {
            return Err(Error::Parse(format!(
                "objective '{head}' takes no ':' argument"
            )));
        }
        obj.validate()?;
        Ok(obj)
    }

    /// Canonical name (the TPW fraction is not encoded).
    pub fn name(self) -> &'static str {
        match self {
            Objective::Throughput => "throughput",
            Objective::EnergyPerTask => "energy",
            Objective::Edp => "edp",
            Objective::ThroughputPerWatt { .. } => "tpw",
        }
    }

    /// Is this the plain throughput axis (every pre-objective path)?
    pub fn is_throughput(self) -> bool {
        matches!(self, Objective::Throughput)
    }

    /// Reject out-of-range parameters.
    pub fn validate(self) -> Result<()> {
        if let Objective::ThroughputPerWatt { min_x_frac } = self {
            if !min_x_frac.is_finite() || min_x_frac <= 0.0 || min_x_frac > 1.0 {
                return Err(Error::Config(format!(
                    "min-X fraction {min_x_frac} outside (0, 1]"
                )));
            }
        }
        Ok(())
    }
}

/// The per-device power model a solve and a simulation share:
/// 𝒫_ij = `coeff`·μ_ij^α for a busy device (the §3.2 exponential
/// power/performance relation) plus an `idle_power` floor drawn by an
/// *empty* device.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PowerProfile {
    /// Power coefficient k of Def. 4 (must be finite and > 0).
    pub coeff: f64,
    /// Power scenario (α ≤ 1).
    pub scenario: PowerScenario,
    /// Power drawn by an idle (empty) device; ≥ 0, default 0 — the
    /// pre-objective behavior, where empty devices cost nothing.
    pub idle_power: f64,
}

impl Default for PowerProfile {
    fn default() -> Self {
        Self {
            coeff: 1.0,
            scenario: PowerScenario::Proportional,
            idle_power: 0.0,
        }
    }
}

impl PowerProfile {
    /// Profile with the given dynamic-power model and no idle floor.
    pub fn new(coeff: f64, scenario: PowerScenario) -> Self {
        Self { coeff, scenario, idle_power: 0.0 }
    }

    /// Builder: attach an idle-power floor.
    pub fn with_idle(mut self, idle_power: f64) -> Self {
        self.idle_power = idle_power;
        self
    }

    /// The α exponent of the scenario.
    pub fn alpha(&self) -> f64 {
        self.scenario.alpha()
    }

    /// Dynamic power of a task executing at `rate`: coeff·rate^α — the
    /// same formula as [`AffinityMatrix::power_matrix`], usable on
    /// drifted physical rates the matrix does not know about.
    pub fn task_power(&self, rate: f64) -> f64 {
        self.coeff * rate.powf(self.alpha())
    }

    /// Reject invalid parameters (same envelope as
    /// [`crate::model::energy::EnergyModel::new`], plus the idle floor).
    pub fn validate(&self) -> Result<()> {
        if self.coeff <= 0.0 || !self.coeff.is_finite() {
            return Err(Error::Config(format!("power coefficient {}", self.coeff)));
        }
        if self.alpha() > 1.0 {
            return Err(Error::Config(format!(
                "α = {} > 1 is outside the paper's power model",
                self.alpha()
            )));
        }
        if self.idle_power < 0.0 || !self.idle_power.is_finite() {
            return Err(Error::Config(format!("idle power {}", self.idle_power)));
        }
        Ok(())
    }
}

/// Objective-scored incremental evaluator: [`IncrementalX`] plus
/// per-column power numerators, scoring any [`Objective`] with the same
/// probe complexity the throughput greedy loop enjoys.
///
/// System power is 𝒫_sys = Σ_j 𝒫_col(j), where a busy column
/// contributes its Eq.-19 term Σ_i (N_ij/occ_j)·𝒫_ij and an empty
/// column contributes the idle floor.  Then
///
/// * E[ℰ] = 𝒫_sys / X (Eq. 19, extended by the idle floor),
/// * EDP  = E[ℰ]·N/X (Eq. 21),
/// * perf-per-watt = X / 𝒫_sys.
///
/// A move touches exactly two columns, so given the current
/// [`base`](Self::base) pair, [`probe`](Self::probe) is O(1).
#[derive(Debug, Clone)]
pub struct ObjectiveEval {
    inc: IncrementalX,
    /// Row-major k×l power matrix 𝒫_ij.
    power: Vec<f64>,
    /// Per-column Σ_i N_ij·𝒫_ij.
    pnum: Vec<f64>,
    l: usize,
    idle: f64,
    /// Total tasks N (constant across moves).
    n_total: f64,
    objective: Objective,
    /// Unconstrained throughput optimum X* (only read by the
    /// ThroughputPerWatt feasibility check).
    x_ref: f64,
}

impl ObjectiveEval {
    /// Build the caches from a full state (O(k·l), once).  `x_ref` is
    /// the unconstrained throughput optimum for the
    /// [`Objective::ThroughputPerWatt`] floor; pass 0.0 for the other
    /// objectives.
    pub fn new(
        mu: &AffinityMatrix,
        n: &StateMatrix,
        profile: &PowerProfile,
        objective: Objective,
        x_ref: f64,
    ) -> Result<Self> {
        profile.validate()?;
        objective.validate()?;
        let (k, l) = (mu.types(), mu.procs());
        let power = mu.power_matrix(profile.coeff, profile.alpha());
        let mut pnum = vec![0.0f64; l];
        for j in 0..l {
            for i in 0..k {
                pnum[j] += n.get(i, j) as f64 * power[i * l + j];
            }
        }
        Ok(Self {
            inc: IncrementalX::new(mu, n),
            power,
            pnum,
            l,
            idle: profile.idle_power,
            n_total: n.total() as f64,
            objective,
            x_ref,
        })
    }

    /// The objective being scored.
    pub fn objective(&self) -> Objective {
        self.objective
    }

    /// Column j's contribution to 𝒫_sys: the Eq.-19 term when busy,
    /// the idle floor when empty.
    #[inline]
    fn col_power(&self, j: usize) -> f64 {
        let occ = self.inc.occupancy(j);
        if occ == 0.0 {
            self.idle
        } else {
            self.pnum[j] / occ
        }
    }

    /// System throughput X (Eq. 28), O(l) from the caches.
    pub fn x(&self) -> f64 {
        self.inc.x()
    }

    /// System power 𝒫_sys, O(l) from the caches.
    pub fn total_power(&self) -> f64 {
        (0..self.l).map(|j| self.col_power(j)).sum()
    }

    /// E[ℰ] (Eq. 19 + idle floor); +∞ on a drained system.
    pub fn energy_per_task(&self) -> f64 {
        let x = self.x();
        if x <= 0.0 {
            return f64::INFINITY;
        }
        self.total_power() / x
    }

    /// EDP (Eq. 21); +∞ on a drained system.
    pub fn edp(&self) -> f64 {
        let x = self.x();
        if x <= 0.0 {
            return f64::INFINITY;
        }
        self.energy_per_task() * (self.n_total / x)
    }

    /// Current (X, 𝒫_sys) pair — the probe baseline, O(l).
    pub fn base(&self) -> (f64, f64) {
        (self.x(), self.total_power())
    }

    /// O(1) probe: the (X, 𝒫_sys) pair after moving one p-type task
    /// from column `from` to column `to`, given the current
    /// [`base`](Self::base).  Defined for `from ≠ to` and
    /// `N[p][from] > 0` (caller-checked, as with
    /// [`IncrementalX::delta_minus`]).
    pub fn probe(&self, p: usize, from: usize, to: usize, base: (f64, f64)) -> (f64, f64) {
        debug_assert_ne!(from, to);
        let (x0, p0) = base;
        let x2 = x0 + self.inc.delta_minus(p, from) + self.inc.delta_plus(p, to);
        // Column `from` loses the task …
        let occ_f = self.inc.occupancy(from);
        let occ_f2 = occ_f - 1.0;
        let cf_new = if occ_f2 <= 0.0 {
            self.idle
        } else {
            (self.pnum[from] - self.power[p * self.l + from]) / occ_f2
        };
        // … and column `to` gains it.
        let occ_t = self.inc.occupancy(to);
        let ct_new = (self.pnum[to] + self.power[p * self.l + to]) / (occ_t + 1.0);
        let p2 = p0 - self.col_power(from) - self.col_power(to) + cf_new + ct_new;
        (x2, p2)
    }

    /// Score an (X, 𝒫_sys) pair under the objective; higher is better
    /// for every objective (minimized quantities are negated).
    pub fn score_of(&self, x: f64, power: f64) -> f64 {
        match self.objective {
            Objective::Throughput => x,
            Objective::EnergyPerTask => {
                if x <= 0.0 {
                    f64::NEG_INFINITY
                } else {
                    -(power / x)
                }
            }
            Objective::Edp => {
                if x <= 0.0 {
                    f64::NEG_INFINITY
                } else {
                    -(power / x * (self.n_total / x))
                }
            }
            Objective::ThroughputPerWatt { .. } => {
                if power <= 0.0 {
                    0.0
                } else {
                    x / power
                }
            }
        }
    }

    /// May the solver stand at throughput `x`?  Always true except under
    /// the ThroughputPerWatt floor X ≥ min_x_frac·X*.
    pub fn feasible(&self, x: f64) -> bool {
        match self.objective {
            Objective::ThroughputPerWatt { min_x_frac } => {
                x >= min_x_frac * self.x_ref - 1e-12
            }
            _ => true,
        }
    }

    /// Score at the current state.
    pub fn score(&self) -> f64 {
        let (x, p) = self.base();
        self.score_of(x, p)
    }

    /// The objective's reported magnitude at the current state (X, E,
    /// EDP or X/𝒫 — *not* sign-flipped like [`score`](Self::score)).
    pub fn objective_value(&self) -> f64 {
        match self.objective {
            Objective::Throughput => self.x(),
            Objective::EnergyPerTask => self.energy_per_task(),
            Objective::Edp => self.edp(),
            Objective::ThroughputPerWatt { .. } => {
                let (x, p) = self.base();
                if p <= 0.0 {
                    0.0
                } else {
                    x / p
                }
            }
        }
    }

    /// Apply a GrIn move (one p-type task from `from` to `to`) to the
    /// caches, O(1).
    pub fn apply_move(&mut self, p: usize, from: usize, to: usize) {
        self.inc.apply_move(p, from, to);
        self.pnum[from] -= self.power[p * self.l + from];
        if self.inc.occupancy(from) == 0.0 {
            // Cancel rounding dust on emptied columns, mirroring
            // IncrementalX::recache.
            self.pnum[from] = 0.0;
        }
        self.pnum[to] += self.power[p * self.l + to];
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::energy::EnergyModel;
    use crate::model::throughput::x_of_state;
    use crate::sim::rng::Rng;

    #[test]
    fn objective_parsing_round_trips_and_validates() {
        assert_eq!(Objective::parse("throughput").unwrap(), Objective::Throughput);
        assert_eq!(Objective::parse("x").unwrap(), Objective::Throughput);
        assert_eq!(Objective::parse("energy").unwrap(), Objective::EnergyPerTask);
        assert_eq!(Objective::parse("energy_per_task").unwrap(), Objective::EnergyPerTask);
        assert_eq!(Objective::parse("EDP").unwrap(), Objective::Edp);
        assert_eq!(
            Objective::parse("tpw").unwrap(),
            Objective::ThroughputPerWatt { min_x_frac: DEFAULT_MIN_X_FRAC }
        );
        assert_eq!(
            Objective::parse("tpw:0.75").unwrap(),
            Objective::ThroughputPerWatt { min_x_frac: 0.75 }
        );
        assert!(Objective::parse("tpw:1.5").is_err());
        assert!(Objective::parse("tpw:zero").is_err());
        assert!(Objective::parse("energy:0.5").is_err());
        assert!(Objective::parse("latency").is_err());
        assert!(Objective::ThroughputPerWatt { min_x_frac: 0.0 }.validate().is_err());
        for o in [Objective::Throughput, Objective::EnergyPerTask, Objective::Edp] {
            assert_eq!(Objective::parse(o.name()).unwrap(), o);
        }
        assert!(Objective::default().is_throughput());
    }

    #[test]
    fn power_profile_validates_and_scales() {
        assert!(PowerProfile::default().validate().is_ok());
        assert!(PowerProfile::new(0.0, PowerScenario::Constant).validate().is_err());
        assert!(PowerProfile::new(1.0, PowerScenario::Exponent(1.5)).validate().is_err());
        assert!(PowerProfile::default().with_idle(-1.0).validate().is_err());
        let p = PowerProfile::new(2.0, PowerScenario::Exponent(0.5));
        assert!((p.task_power(4.0) - 4.0).abs() < 1e-12); // 2·4^0.5
        assert!((PowerProfile::default().task_power(7.0) - 7.0).abs() < 1e-12);
    }

    #[test]
    fn eval_matches_energy_model_without_idle_floor() {
        // With idle_power = 0 the evaluator is exactly Eq. 19/21.
        let mut rng = Rng::new(1312);
        for _ in 0..30 {
            let k = 2 + rng.index(3);
            let l = 2 + rng.index(3);
            let rows: Vec<Vec<f64>> = (0..k)
                .map(|_| (0..l).map(|_| rng.range_f64(0.5, 30.0)).collect())
                .collect();
            let mu = AffinityMatrix::from_rows(&rows).unwrap();
            let mut s = StateMatrix::zeros(k, l);
            for i in 0..k {
                for j in 0..l {
                    s.set(i, j, rng.below(4) as u32);
                }
            }
            if s.total() == 0 {
                s.set(0, 0, 1);
            }
            for scenario in [
                PowerScenario::Constant,
                PowerScenario::Proportional,
                PowerScenario::Exponent(0.5),
            ] {
                let profile = PowerProfile::new(1.7, scenario);
                let em = EnergyModel::new(&mu, profile.coeff, scenario).unwrap();
                let eval =
                    ObjectiveEval::new(&mu, &s, &profile, Objective::EnergyPerTask, 0.0).unwrap();
                assert!(
                    (eval.energy_per_task() - em.energy_per_task(&mu, &s)).abs() < 1e-9,
                    "energy mismatch"
                );
                assert!((eval.edp() - em.edp(&mu, &s)).abs() < 1e-9, "edp mismatch");
                assert!((eval.x() - x_of_state(&mu, &s)).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn probe_and_apply_match_full_rebuild() {
        let mu = AffinityMatrix::from_rows(&[
            vec![10.0, 2.0, 4.0],
            vec![1.0, 8.0, 3.0],
            vec![5.0, 5.0, 9.0],
        ])
        .unwrap();
        let mut s = StateMatrix::new(3, 3, vec![3, 1, 0, 2, 4, 1, 0, 2, 5]).unwrap();
        let profile = PowerProfile::new(1.0, PowerScenario::Exponent(0.5)).with_idle(0.3);
        for objective in [
            Objective::EnergyPerTask,
            Objective::Edp,
            Objective::ThroughputPerWatt { min_x_frac: 0.5 },
        ] {
            let mut eval = ObjectiveEval::new(&mu, &s.clone(), &profile, objective, 10.0).unwrap();
            let moves = [(0usize, 0usize, 1usize), (1, 1, 2), (2, 2, 0), (0, 0, 2), (1, 2, 0)];
            for &(p, from, to) in &moves {
                if s.get(p, from) == 0 {
                    continue;
                }
                let base = eval.base();
                let (x2, p2) = eval.probe(p, from, to, base);
                s.move_task(p, from, to).unwrap();
                eval.apply_move(p, from, to);
                let fresh = ObjectiveEval::new(&mu, &s, &profile, objective, 10.0).unwrap();
                let (xf, pf) = fresh.base();
                assert!((x2 - xf).abs() < 1e-9, "probe X {x2} vs fresh {xf}");
                assert!((p2 - pf).abs() < 1e-9, "probe 𝒫 {p2} vs fresh {pf}");
                assert!((eval.score() - fresh.score()).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn idle_floor_charges_empty_columns() {
        let mu = AffinityMatrix::two_type(20.0, 15.0, 3.0, 8.0).unwrap();
        // Everything on processor 0 — processor 1 is drained.
        let s = StateMatrix::new(2, 2, vec![4, 0, 4, 0]).unwrap();
        let hot = PowerProfile::new(1.0, PowerScenario::Constant).with_idle(2.5);
        let cold = PowerProfile::new(1.0, PowerScenario::Constant);
        let with_idle = ObjectiveEval::new(&mu, &s, &hot, Objective::EnergyPerTask, 0.0).unwrap();
        let without = ObjectiveEval::new(&mu, &s, &cold, Objective::EnergyPerTask, 0.0).unwrap();
        assert!((with_idle.total_power() - without.total_power() - 2.5).abs() < 1e-12);
        // The drained column's idle draw lands in E[ℰ].
        let x = x_of_state(&mu, &s);
        assert!((with_idle.energy_per_task() - without.energy_per_task() - 2.5 / x).abs() < 1e-12);
    }

    #[test]
    fn tpw_feasibility_floors_throughput() {
        let mu = AffinityMatrix::two_type(20.0, 15.0, 3.0, 8.0).unwrap();
        let s = StateMatrix::new(2, 2, vec![1, 9, 0, 10]).unwrap();
        let profile = PowerProfile::default();
        let eval = ObjectiveEval::new(
            &mu,
            &s,
            &profile,
            Objective::ThroughputPerWatt { min_x_frac: 0.9 },
            30.0,
        )
        .unwrap();
        assert!(eval.feasible(27.0));
        assert!(!eval.feasible(26.9));
        // Other objectives have no floor.
        let free = ObjectiveEval::new(&mu, &s, &profile, Objective::Edp, 30.0).unwrap();
        assert!(free.feasible(0.0));
    }
}
