//! `hetsched` — the launcher binary.
//!
//! See `hetsched help` (cli::commands::USAGE) for the command surface.

use hetsched::cli::{commands, Args};

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    if let Err(e) = commands::run(&args) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}
