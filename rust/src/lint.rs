//! In-repo source lint engine behind the `srclint` bin (`cargo run
//! --bin srclint`).  Zero dependencies, like every other substrate in
//! the crate: the shared lexer ([`crate::analysis::lexer`]) masks
//! comments, strings and char literals out of each source file, and a
//! handful of textual rules then enforce repo invariants that
//! `rustc`/clippy cannot see (the AST-level analyses live one layer
//! up, in [`crate::analysis`] behind the `detlint` bin):
//!
//! | rule                 | invariant                                              |
//! |----------------------|--------------------------------------------------------|
//! | `raw-sync`           | no `std::sync::` outside `src/sync/` (use `crate::sync`; `std::sync::mpsc` exempt) |
//! | `hot-path-panic`     | no `unwrap`/`expect`/`panic!`/`unreachable!` in hot-path modules (`sim/`, `coordinator/frontend.rs`, `policy/target.rs`) |
//! | `partial-cmp`        | no `partial_cmp` (floats must use `total_cmp`)         |
//! | `instant-now`        | no `Instant::now` outside `impl ... Clock for` blocks  |
//! | `ordering-rationale` | every memory-`Ordering` use carries an `// ordering:` rationale comment |
//!
//! `#[cfg(test)]` modules are exempt from every rule.  Individual
//! sites are suppressed with `// srclint: allow(<rule>) — <reason>`
//! on the same line or the line above; the reason is mandatory (an
//! allow without a justification is itself a finding).

use crate::analysis::lexer::{allow_at, mask, Masked};
use std::fs;
use std::path::{Path, PathBuf};

/// One rule violation at a source location.
#[derive(Clone, Debug)]
pub struct Finding {
    /// Path relative to the lint root (e.g. `coordinator/frontend.rs`).
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    pub rule: &'static str,
    pub message: String,
}

impl std::fmt::Display for Finding {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.message)
    }
}

/// Hot-path modules where panicking is banned (prefix match on the
/// path relative to `src/`).
const HOT_PATHS: &[&str] = &["sim/", "coordinator/frontend.rs", "policy/target.rs"];

/// Memory-ordering variants that require a rationale comment.
const ORDERINGS: &[&str] = &[
    "Ordering::Relaxed",
    "Ordering::Acquire",
    "Ordering::Release",
    "Ordering::AcqRel",
    "Ordering::SeqCst",
];

// ---------------------------------------------------------------------------
// Region detection (test modules, Clock impls)
// ---------------------------------------------------------------------------

/// Mark the lines covered by a brace-delimited block that starts at (or
/// just after) `start`, in `exempt`.
fn mark_block(code: &[String], start: usize, exempt: &mut [bool]) {
    let mut depth = 0i32;
    let mut seen_open = false;
    for (li, line) in code.iter().enumerate().skip(start) {
        for ch in line.chars() {
            if ch == '{' {
                depth += 1;
                seen_open = true;
            } else if ch == '}' {
                depth -= 1;
            }
        }
        exempt[li] = true;
        if seen_open && depth <= 0 {
            return;
        }
    }
}

/// Lines inside `#[cfg(test)] mod` regions (all rules skip these).
fn test_regions(code: &[String]) -> Vec<bool> {
    let mut exempt = vec![false; code.len()];
    for i in 0..code.len() {
        if code[i].contains("#[cfg(") && code[i].contains("test") {
            // The cfg may gate a `mod tests` a line or two below.
            let lookahead = (i + 3).min(code.len());
            if code[i..lookahead].iter().any(|l| {
                l.split_whitespace().any(|w| w == "mod")
                    || l.contains("mod tests")
                    || l.contains("pub mod")
            }) {
                mark_block(code, i, &mut exempt);
            }
        }
    }
    exempt
}

/// Lines inside `impl ... Clock for ...` blocks (exempt from
/// `instant-now`: a Clock impl is exactly where wall time belongs).
fn clock_impl_regions(code: &[String]) -> Vec<bool> {
    let mut exempt = vec![false; code.len()];
    for i in 0..code.len() {
        let l = &code[i];
        if l.contains("impl") && l.contains("Clock for") {
            mark_block(code, i, &mut exempt);
        }
    }
    exempt
}

/// True if an `ordering:` rationale comment covers line `li`: on the
/// same line, or in the comment block above the enclosing statement
/// (the search walks up through pure-comment lines and the lines of
/// the statement itself, and stops at a blank line or after crossing
/// one complete earlier statement).
fn ordering_rationale_near(m: &Masked, li: usize) -> bool {
    if m.comments[li].contains("ordering:") {
        return true;
    }
    let mut i = li;
    let mut crossed_stmt = false;
    while i > 0 {
        i -= 1;
        if m.comments[i].contains("ordering:") {
            return true;
        }
        let code = m.code[i].trim();
        if code.is_empty() {
            if m.comments[i].trim().is_empty() {
                return false; // blank line ends the search
            }
            continue; // pure comment line
        }
        if code.contains(';') || code.contains('{') || code.contains('}') {
            if crossed_stmt {
                return false;
            }
            crossed_stmt = true;
        }
    }
    false
}

// ---------------------------------------------------------------------------
// Rules
// ---------------------------------------------------------------------------

fn check_line(
    rel: &str,
    li: usize,
    m: &Masked,
    in_test: bool,
    in_clock_impl: bool,
    out: &mut Vec<Finding>,
) {
    if in_test {
        return;
    }
    let code = &m.code[li];
    let in_sync = rel.starts_with("sync/");
    let hot = HOT_PATHS.iter().any(|p| rel.starts_with(p));
    let mut report = |rule: &'static str, message: String| match allow_at(&m.comments, li, rule) {
        Some(true) => {}
        Some(false) => out.push(Finding {
            file: rel.to_string(),
            line: li + 1,
            rule,
            message: format!("suppression without a justification: {message}"),
        }),
        None => out.push(Finding { file: rel.to_string(), line: li + 1, rule, message }),
    };

    if !in_sync && code.contains("std::sync::") && !code.contains("std::sync::mpsc") {
        report(
            "raw-sync",
            "raw std::sync primitive — import from crate::sync so the model checker can \
             instrument it"
                .to_string(),
        );
    }
    if hot {
        for pat in [".unwrap(", ".expect(", "panic!(", "unreachable!("] {
            if code.contains(pat) {
                report(
                    "hot-path-panic",
                    format!("`{pat}` in a hot-path module — return Result or justify inline"),
                );
            }
        }
    }
    if code.contains("partial_cmp") {
        report(
            "partial-cmp",
            "partial_cmp on floats is NaN-unsound — use total_cmp".to_string(),
        );
    }
    if code.contains("Instant::now") && !in_clock_impl {
        report(
            "instant-now",
            "Instant::now outside a Clock impl breaks virtual-time determinism — inject a \
             Clock or justify inline"
                .to_string(),
        );
    }
    if !in_sync {
        for ord in ORDERINGS {
            if code.contains(ord) && !ordering_rationale_near(m, li) {
                report(
                    "ordering-rationale",
                    format!("{ord} without an `// ordering:` rationale comment nearby"),
                );
                break;
            }
        }
    }
}

/// Lint one file's source text.  `rel` is the path relative to the
/// `src/` root, with forward slashes.
pub fn lint_source(rel: &str, src: &str) -> Vec<Finding> {
    let m = mask(src);
    let tests = test_regions(&m.code);
    let clocks = clock_impl_regions(&m.code);
    let mut out = Vec::new();
    for li in 0..m.code.len() {
        check_line(rel, li, &m, tests[li], clocks[li], &mut out);
    }
    out
}

/// Recursively lint every `.rs` file under `src_root`.  Returns the
/// findings and the number of files scanned.
pub fn lint_tree(src_root: &Path) -> std::io::Result<(Vec<Finding>, usize)> {
    let mut files: Vec<PathBuf> = Vec::new();
    collect_rs(src_root, &mut files)?;
    files.sort();
    let mut findings = Vec::new();
    for f in &files {
        let rel = f
            .strip_prefix(src_root)
            .unwrap_or(f)
            .to_string_lossy()
            .replace('\\', "/");
        let src = fs::read_to_string(f)?;
        findings.extend(lint_source(&rel, &src));
    }
    Ok((findings, files.len()))
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    for entry in fs::read_dir(dir)? {
        let entry = entry?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().and_then(|e| e.to_str()) == Some("rs") {
            out.push(path);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rules(findings: &[Finding]) -> Vec<&'static str> {
        findings.iter().map(|f| f.rule).collect()
    }

    #[test]
    fn flags_raw_sync_outside_sync_module() {
        let src = "use std::sync::Mutex;\n";
        assert_eq!(rules(&lint_source("coordinator/foo.rs", src)), ["raw-sync"]);
        assert!(lint_source("sync/model.rs", src).is_empty());
    }

    #[test]
    fn mpsc_is_exempt_from_raw_sync() {
        let src = "use std::sync::mpsc::channel;\n";
        assert!(lint_source("coordinator/foo.rs", src).is_empty());
    }

    #[test]
    fn flags_hot_path_panics_only_in_hot_paths() {
        let src = "fn f(x: Option<u32>) -> u32 { x.unwrap() }\n";
        assert_eq!(rules(&lint_source("sim/engine.rs", src)), ["hot-path-panic"]);
        assert!(lint_source("report/table.rs", src).is_empty());
    }

    #[test]
    fn flags_partial_cmp_and_instant_now() {
        let src = "fn f(a: f64, b: f64) { a.partial_cmp(&b); }\n";
        assert_eq!(rules(&lint_source("policy/x.rs", src)), ["partial-cmp"]);
        let src = "fn t() { let _ = Instant::now(); }\n";
        assert_eq!(rules(&lint_source("policy/x.rs", src)), ["instant-now"]);
    }

    #[test]
    fn clock_impls_may_read_wall_time() {
        let src = "impl Clock for MonotonicClock {\n    fn now(&self) -> Instant { Instant::now() }\n}\n";
        assert!(lint_source("coordinator/batcher.rs", src).is_empty());
    }

    #[test]
    fn ordering_needs_rationale() {
        let bad = "let v = x.load(Ordering::Acquire);\n";
        assert_eq!(rules(&lint_source("coordinator/f.rs", bad)), ["ordering-rationale"]);
        let good = "// ordering: pairs with the Release store in install().\nlet v = x.load(Ordering::Acquire);\n";
        assert!(lint_source("coordinator/f.rs", good).is_empty());
        let same_line = "let v = x.load(Ordering::Relaxed); // ordering: counter, no sync.\n";
        assert!(lint_source("coordinator/f.rs", same_line).is_empty());
    }

    #[test]
    fn allow_requires_justification() {
        let justified =
            "// srclint: allow(partial-cmp) — comparing non-float newtype keys here.\nlet c = a.partial_cmp(&b);\n";
        assert!(lint_source("policy/x.rs", justified).is_empty());
        let bare = "// srclint: allow(partial-cmp)\nlet c = a.partial_cmp(&b);\n";
        let f = lint_source("policy/x.rs", bare);
        assert_eq!(rules(&f), ["partial-cmp"]);
        assert!(f[0].message.contains("justification"));
    }

    #[test]
    fn test_modules_are_exempt() {
        let src = "#[cfg(test)]\nmod tests {\n    fn f() { let _ = Instant::now(); x.unwrap(); }\n}\n";
        assert!(lint_source("sim/engine.rs", src).is_empty());
    }

    #[test]
    fn strings_and_comments_are_masked() {
        let src = "// Instant::now in a comment is fine\nlet s = \"std::sync::Mutex partial_cmp\";\n";
        assert!(lint_source("coordinator/f.rs", src).is_empty());
        let raw = "let s = r#\"Instant::now() panic!(\"x\")\"#;\n";
        assert!(lint_source("sim/engine.rs", raw).is_empty());
    }

    #[test]
    fn char_literals_and_lifetimes_lex() {
        let src = "fn f<'a>(x: &'a str) -> char { let c = '\\''; let d = 'y'; d }\nlet v = q.partial_cmp(&w);\n";
        assert_eq!(rules(&lint_source("policy/x.rs", src)), ["partial-cmp"]);
    }

    #[test]
    fn self_lint_is_clean() {
        // The lint engine's own source (full of rule-pattern strings)
        // must not flag itself.
        let src = include_str!("lint.rs");
        assert!(lint_source("lint.rs", src).is_empty(), "{:?}", lint_source("lint.rs", src));
    }
}
