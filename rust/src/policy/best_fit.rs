//! BF: dispatch each task to its highest-affinity processor (§5 baseline 2).
//!
//! Optimal in the (general-)symmetric regimes (Table 1), suboptimal by up
//! to the Eq.-16/17 gap in the biased regimes.

use super::{Policy, SystemView};
use crate::sim::rng::Rng;

/// The Best-Fit baseline.
#[derive(Debug, Default)]
pub struct BestFit;

impl Policy for BestFit {
    fn name(&self) -> &'static str {
        "BF"
    }

    fn dispatch(&mut self, ttype: usize, view: &SystemView<'_>, _rng: &mut Rng) -> usize {
        view.mu.best_proc(ttype)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::affinity::AffinityMatrix;
    use crate::model::state::StateMatrix;

    #[test]
    fn routes_by_affinity() {
        let mu = AffinityMatrix::two_type(20.0, 15.0, 3.0, 8.0).unwrap();
        let state = StateMatrix::zeros(2, 2);
        let work = vec![0.0; 2];
        let view = SystemView { mu: &mu, state: &state, work: &work, populations: &[1, 1] };
        let mut rng = Rng::new(1);
        let mut p = BestFit;
        assert_eq!(p.dispatch(0, &view, &mut rng), 0); // 20 > 15
        assert_eq!(p.dispatch(1, &view, &mut rng), 1); // 8 > 3
    }
}
