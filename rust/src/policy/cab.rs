//! CAB — Choose-between-Accelerate-the-fastest-and-Best-fit (Lemma 4).
//!
//! The analytically optimal policy for two processor types.  `prepare`
//! classifies the affinity matrix into its Table-1 regime (only the
//! element *ordering* matters, never the values) and fixes the target
//! state S_max:
//!
//! * (general-)symmetric → **BF**: S_max = (N1, N2);
//! * P1-biased → **AF**: S_max = (1, N2) — one lone program on the fast
//!   processor, everyone else on the other (the counter-intuitive case);
//! * P2-biased → **AF**: S_max = (N1, 1);
//! * homogeneous / big.LITTLE-like → any interior state; we pick the
//!   balanced split as canonical.
//!
//! Dispatch then just steers deficits toward S_max ([`super::target`]).

// srclint: allow-file(index-reachable) — CAB tables are k by l, sized at prepare

use super::target::TargetSteering;
use super::{Policy, PreparedTarget, SolveRequest, SystemView};
use crate::error::{Error, Result};
use crate::model::affinity::{AffinityMatrix, Regime};
use crate::model::state::StateMatrix;
use crate::model::throughput::s_max;
use crate::sim::rng::Rng;

/// The CAB policy.
#[derive(Debug, Default)]
pub struct Cab {
    steering: Option<TargetSteering>,
    regime: Option<Regime>,
}

impl Cab {
    /// New, unprepared CAB.
    pub fn new() -> Self {
        Self::default()
    }

    /// The regime classified at prepare time.
    pub fn regime(&self) -> Option<Regime> {
        self.regime
    }

    /// The S_max target solved at prepare time.
    pub fn target(&self) -> Option<&StateMatrix> {
        self.steering.as_ref().map(|s| s.target())
    }

    /// Compute the CAB target state for a classified system.
    pub fn target_state(
        mu: &AffinityMatrix,
        populations: &[u32],
    ) -> Result<(Regime, StateMatrix)> {
        if populations.len() != 2 || mu.types() != 2 || mu.procs() != 2 {
            return Err(Error::Shape(
                "CAB is the two-type analytical policy; use GrIn for k,l > 2".into(),
            ));
        }
        let (n1, n2) = (populations[0], populations[1]);
        let regime = mu.classify()?;
        let (t11, t22) = s_max(regime, n1, n2);
        Ok((regime, StateMatrix::from_two_type(t11, t22, n1, n2)?))
    }
}

impl Policy for Cab {
    fn name(&self) -> &'static str {
        "CAB"
    }

    /// CAB is objective- and weight-blind: only baseline requests
    /// (throughput, no effective weights) are accepted — anything else
    /// fails loudly via [`SolveRequest::ensure_baseline`].
    fn prepare(&mut self, req: &SolveRequest<'_>) -> Result<PreparedTarget> {
        req.ensure_baseline(self.name())?;
        let (regime, target) = Self::target_state(req.mu, req.populations)?;
        self.regime = Some(regime);
        let x = crate::model::throughput::x_of_state(req.mu, &target);
        self.steering = Some(TargetSteering::new(target.clone()));
        Ok(PreparedTarget { target: Some(target), objective_value: Some(x) })
    }

    fn dispatch(&mut self, ttype: usize, view: &SystemView<'_>, _rng: &mut Rng) -> usize {
        self.steering
            .as_ref()
            // srclint: allow(panic-reachable) — dispatch is specified to follow prepare(); violating that is a caller bug worth a loud stop
            .expect("CAB::prepare must be called before dispatch")
            .dispatch(ttype, view)
            // srclint: allow(panic-reachable) — steering spans the full fleet, so some device always matches
            .expect("steering over the full fleet always yields a device")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::throughput::{x_max_theoretical, x_of_state};

    #[test]
    fn p1_biased_targets_af() {
        let mu = AffinityMatrix::two_type(20.0, 15.0, 3.0, 8.0).unwrap();
        let (regime, target) = Cab::target_state(&mu, &[10, 10]).unwrap();
        assert_eq!(regime, Regime::P1Biased);
        assert_eq!(target.get(0, 0), 1); // lone fast program
        assert_eq!(target.get(0, 1), 9);
        assert_eq!(target.get(1, 0), 0);
        assert_eq!(target.get(1, 1), 10);
        // And this target achieves exactly the Eq. 16 optimum.
        let x = x_of_state(&mu, &target);
        let want = x_max_theoretical(&mu, Regime::P1Biased, 10, 10);
        assert!((x - want).abs() < 1e-12);
    }

    #[test]
    fn p2_biased_targets_af() {
        let mu = AffinityMatrix::two_type(253.0, 0.911, 587.0, 2398.0).unwrap();
        let (regime, target) = Cab::target_state(&mu, &[6, 14]).unwrap();
        assert_eq!(regime, Regime::P2Biased);
        assert_eq!(target.get(0, 0), 6);
        assert_eq!(target.get(1, 1), 1);
        assert_eq!(target.get(1, 0), 13);
    }

    #[test]
    fn general_symmetric_targets_bf() {
        let mu = AffinityMatrix::two_type(928.0, 3.61, 587.0, 2398.0).unwrap();
        let (regime, target) = Cab::target_state(&mu, &[7, 13]).unwrap();
        assert_eq!(regime, Regime::GeneralSymmetric);
        assert_eq!(target.get(0, 0), 7);
        assert_eq!(target.get(1, 1), 13);
        assert_eq!(target.get(0, 1), 0);
        assert_eq!(target.get(1, 0), 0);
    }

    #[test]
    fn cab_target_beats_every_state_exhaustively() {
        // Lemma 4: S_max really is argmax over the whole state grid.
        for (mu, pops) in [
            (AffinityMatrix::two_type(20.0, 15.0, 3.0, 8.0).unwrap(), [6u32, 6u32]),
            (AffinityMatrix::two_type(9.0, 2.0, 1.0, 7.0).unwrap(), [5, 7]),
            (AffinityMatrix::two_type(3.0, 2.0, 8.0, 9.0).unwrap(), [4, 8]),
        ] {
            let (_, target) = Cab::target_state(&mu, &pops).unwrap();
            let best = x_of_state(&mu, &target);
            for n11 in 0..=pops[0] {
                for n22 in 0..=pops[1] {
                    let s =
                        StateMatrix::from_two_type(n11, n22, pops[0], pops[1]).unwrap();
                    assert!(
                        x_of_state(&mu, &s) <= best + 1e-9,
                        "state ({n11},{n22}) beats CAB for {mu:?}"
                    );
                }
            }
        }
    }

    #[test]
    fn rejects_wrong_arity() {
        let mu = AffinityMatrix::from_rows(&[
            vec![1.0, 2.0, 3.0],
            vec![3.0, 2.0, 1.0],
        ])
        .unwrap();
        assert!(Cab::target_state(&mu, &[1, 2]).is_err());
    }

    #[test]
    fn dispatch_without_prepare_panics() {
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mu = AffinityMatrix::two_type(20.0, 15.0, 3.0, 8.0).unwrap();
            let state = StateMatrix::zeros(2, 2);
            let work = vec![0.0; 2];
            let view = SystemView {
                mu: &mu,
                state: &state,
                work: &work,
                populations: &[1, 1],
            };
            Cab::new().dispatch(0, &view, &mut Rng::new(0))
        }));
        assert!(result.is_err());
    }
}
