//! JSQ: join the queue with the fewest tasks (§5 baseline 4).
//!
//! Ties break toward the arriving task's fastest processor, then the
//! lowest index (deterministic for reproducible figures).

use super::{Policy, SystemView};
use crate::sim::rng::Rng;

/// The Join-the-Shortest-Queue baseline.
#[derive(Debug, Default)]
pub struct Jsq;

impl Policy for Jsq {
    fn name(&self) -> &'static str {
        "JSQ"
    }

    fn dispatch(&mut self, ttype: usize, view: &SystemView<'_>, _rng: &mut Rng) -> usize {
        let l = view.mu.procs();
        let mut best = 0usize;
        let mut best_occ = u32::MAX;
        let mut best_rate = f64::NEG_INFINITY;
        for j in 0..l {
            let occ = view.state.col_sum(j);
            let rate = view.mu.rate(ttype, j);
            if occ < best_occ || (occ == best_occ && rate > best_rate) {
                best = j;
                best_occ = occ;
                best_rate = rate;
            }
        }
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::affinity::AffinityMatrix;
    use crate::model::state::StateMatrix;

    #[test]
    fn picks_emptiest_queue() {
        let mu = AffinityMatrix::two_type(20.0, 15.0, 3.0, 8.0).unwrap();
        let state = StateMatrix::new(2, 2, vec![3, 1, 2, 0]).unwrap(); // cols: 5, 1
        let work = vec![0.0; 2];
        let view = SystemView { mu: &mu, state: &state, work: &work, populations: &[4, 2] };
        let mut p = Jsq;
        let mut rng = Rng::new(0);
        assert_eq!(p.dispatch(0, &view, &mut rng), 1);
    }

    #[test]
    fn tie_breaks_toward_affinity() {
        let mu = AffinityMatrix::two_type(20.0, 15.0, 3.0, 8.0).unwrap();
        let state = StateMatrix::zeros(2, 2);
        let work = vec![0.0; 2];
        let view = SystemView { mu: &mu, state: &state, work: &work, populations: &[1, 1] };
        let mut p = Jsq;
        let mut rng = Rng::new(0);
        assert_eq!(p.dispatch(0, &view, &mut rng), 0); // equal occupancy: 20 > 15
        assert_eq!(p.dispatch(1, &view, &mut rng), 1); // 8 > 3
    }
}
