//! LB: load balancing with perfect information (§5 baseline 3).
//!
//! The paper's definition, verbatim: "dispatch the task to balance the
//! load of the processors, i.e., send it to the queue with the least
//! amount of work.  Work is defined as the task total size in the queue"
//! — with *true* task sizes (perfect information), which "will only give
//! better results than using estimations".
//!
//! Deliberately, LB does **not** account for the arriving task's own
//! prospective service time on the candidate processor — that is the
//! whole reason it collapses in affinity systems (a queue-empty slow
//! processor looks attractive), which the paper's 2.37×–9.07× platform
//! gaps quantify.  Ties break toward the task's faster processor.

// srclint: allow-file(index-reachable) — the load vector is sized by the processor count

use super::{Policy, SystemView};
use crate::sim::rng::Rng;

/// The perfect-information Load-Balancing baseline.
#[derive(Debug, Default)]
pub struct LoadBalance;

impl Policy for LoadBalance {
    fn name(&self) -> &'static str {
        "LB"
    }

    fn needs_work_estimate(&self) -> bool {
        true
    }

    fn dispatch(&mut self, ttype: usize, view: &SystemView<'_>, _rng: &mut Rng) -> usize {
        let l = view.mu.procs();
        let mut best = 0usize;
        let mut best_load = f64::INFINITY;
        let mut best_rate = f64::NEG_INFINITY;
        for j in 0..l {
            let load = view.work[j];
            let rate = view.mu.rate(ttype, j);
            if load < best_load - 1e-12
                || ((load - best_load).abs() <= 1e-12 && rate > best_rate)
            {
                best = j;
                best_load = load;
                best_rate = rate;
            }
        }
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::affinity::AffinityMatrix;
    use crate::model::state::StateMatrix;

    #[test]
    fn balances_by_work_not_count() {
        let mu = AffinityMatrix::two_type(10.0, 10.0, 10.0, 10.0).unwrap();
        let state = StateMatrix::new(2, 2, vec![1, 3, 0, 0]).unwrap();
        // P1 has 1 huge task (10s), P2 has 3 tiny ones (0.3s total).
        let work = vec![10.0, 0.3];
        let view = SystemView { mu: &mu, state: &state, work: &work, populations: &[4, 0] };
        let mut p = LoadBalance;
        let mut rng = Rng::new(0);
        assert_eq!(p.dispatch(0, &view, &mut rng), 1);
    }

    #[test]
    fn ignores_own_service_time_by_design() {
        // The paper's LB: an empty queue wins even if this task is 100×
        // slower there — the affinity-blindness the paper exploits.
        let mu = AffinityMatrix::two_type(0.1, 10.0, 0.1, 10.0).unwrap();
        let state = StateMatrix::zeros(2, 2);
        let work = vec![0.0, 5.0];
        let view = SystemView { mu: &mu, state: &state, work: &work, populations: &[1, 1] };
        let mut p = LoadBalance;
        let mut rng = Rng::new(0);
        assert_eq!(p.dispatch(0, &view, &mut rng), 0);
    }

    #[test]
    fn ties_break_toward_affinity() {
        let mu = AffinityMatrix::two_type(20.0, 15.0, 3.0, 8.0).unwrap();
        let state = StateMatrix::zeros(2, 2);
        let work = vec![0.0, 0.0];
        let view = SystemView { mu: &mu, state: &state, work: &work, populations: &[1, 1] };
        let mut p = LoadBalance;
        let mut rng = Rng::new(0);
        assert_eq!(p.dispatch(0, &view, &mut rng), 0); // 20 > 15
        assert_eq!(p.dispatch(1, &view, &mut rng), 1); // 8 > 3
    }
}
