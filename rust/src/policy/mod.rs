//! Task scheduling policies (§3, §5).
//!
//! The policy answers one question on every task arrival: *which processor
//! gets the next task of type i?*  The simulator, the platform emulator
//! and the serving coordinator all drive the same [`Policy`] trait, so a
//! policy validated in simulation runs unmodified on the live system —
//! exactly the paper's methodology (§5 simulation → §7 platform).
//!
//! Implementations:
//!
//! * [`cab`] — the optimal two-type policy (Lemma 4 / Table 1).
//! * [`grin`] — the GrIn heuristic (Algorithms 1–2) for any k×l.
//! * [`best_fit`], [`random`], [`jsq`], [`load_balance`] — the §5
//!   baselines.
//! * [`opt`] — exhaustive-search oracle ("Opt" in Figs. 9–12).
//! * [`target`] — shared deficit-steering machinery for all state-target
//!   policies (CAB / GrIn / Opt).

pub mod best_fit;
pub mod cab;
pub mod grin;
pub mod jsq;
pub mod myopic;
pub mod load_balance;
pub mod opt;
pub mod random;
pub mod target;

use crate::error::{Error, Result};
use crate::model::affinity::AffinityMatrix;
use crate::model::state::StateMatrix;
use crate::sim::rng::Rng;

/// Snapshot of the system handed to a policy at dispatch time.
#[derive(Debug)]
pub struct SystemView<'a> {
    /// Affinity matrix μ.
    pub mu: &'a AffinityMatrix,
    /// Current task distribution (the departing task already removed).
    pub state: &'a StateMatrix,
    /// Remaining work per processor in drain-time units (perfect
    /// information, as granted to LB in §5).
    pub work: &'a [f64],
    /// Per-type populations N_i.
    pub populations: &'a [u32],
}

/// A task-to-processor dispatch policy.
pub trait Policy: Send {
    /// Display name (figure legends).
    fn name(&self) -> &'static str;

    /// Called once before a run with the system parameters; state-target
    /// policies solve for S_max here.
    fn prepare(&mut self, mu: &AffinityMatrix, populations: &[u32]) -> Result<()> {
        let _ = (mu, populations);
        Ok(())
    }

    /// Priority-aware [`prepare`](Self::prepare): solve under per-cell
    /// steering weights (row-major k×l, priority × estimate confidence —
    /// see [`grin::priority_weights`]).  The default accepts only a
    /// *uniform* weight vector (it reduces to the unweighted solve) and
    /// rejects anything else, so a priority-configured run on a policy
    /// that cannot honor weights fails loudly instead of silently
    /// scheduling unweighted.  GrIn overrides this with the real
    /// weighted solve ([`grin::solve_weighted`]).
    fn prepare_weighted(
        &mut self,
        mu: &AffinityMatrix,
        populations: &[u32],
        weights: &[f64],
    ) -> Result<()> {
        if weights.len() != mu.types() * mu.procs() {
            return Err(Error::Shape(format!(
                "{} weights for a {}×{} system",
                weights.len(),
                mu.types(),
                mu.procs()
            )));
        }
        if weights.windows(2).all(|w| (w[0] - w[1]).abs() <= 1e-12) {
            return self.prepare(mu, populations);
        }
        Err(Error::Config(format!(
            "policy {} does not support priority weights (use grin)",
            self.name()
        )))
    }

    /// Does this policy read `SystemView::work`?  The engine skips the
    /// O(N) remaining-work scan on every dispatch when it doesn't —
    /// a §Perf optimization worth ~2× simulator throughput.
    fn needs_work_estimate(&self) -> bool {
        false
    }

    /// Choose the processor for an arriving task of type `ttype`.
    fn dispatch(&mut self, ttype: usize, view: &SystemView<'_>, rng: &mut Rng) -> usize;
}

/// The policy suite of the paper's figures.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PolicyKind {
    /// CAB (two-type optimal).
    Cab,
    /// GrIn (general near-optimal).
    GrIn,
    /// Best Fit.
    BestFit,
    /// Random.
    Random,
    /// Join-the-Shortest-Queue.
    Jsq,
    /// Load Balancing with perfect information.
    LoadBalance,
    /// Exhaustive-search oracle.
    Opt,
    /// Myopic one-step-lookahead (Ahn et al. [22]; ablation baseline).
    Myopic,
}

impl PolicyKind {
    /// Parse a CLI name.
    pub fn parse(name: &str) -> Result<Self> {
        match name.to_ascii_lowercase().as_str() {
            "cab" => Ok(PolicyKind::Cab),
            "grin" => Ok(PolicyKind::GrIn),
            "bf" | "best_fit" | "bestfit" => Ok(PolicyKind::BestFit),
            "rd" | "random" => Ok(PolicyKind::Random),
            "jsq" => Ok(PolicyKind::Jsq),
            "lb" | "load_balance" => Ok(PolicyKind::LoadBalance),
            "opt" | "exhaustive" => Ok(PolicyKind::Opt),
            "myopic" => Ok(PolicyKind::Myopic),
            other => Err(Error::Parse(format!(
                "unknown policy '{other}' (cab|grin|bf|rd|jsq|lb|opt)"
            ))),
        }
    }

    /// Canonical name.
    pub fn name(self) -> &'static str {
        match self {
            PolicyKind::Cab => "CAB",
            PolicyKind::GrIn => "GrIn",
            PolicyKind::BestFit => "BF",
            PolicyKind::Random => "RD",
            PolicyKind::Jsq => "JSQ",
            PolicyKind::LoadBalance => "LB",
            PolicyKind::Opt => "Opt",
            PolicyKind::Myopic => "Myopic",
        }
    }

    /// Instantiate.
    pub fn build(self) -> Box<dyn Policy> {
        match self {
            PolicyKind::Cab => Box::new(cab::Cab::new()),
            PolicyKind::GrIn => Box::new(grin::GrInPolicy::new()),
            PolicyKind::BestFit => Box::new(best_fit::BestFit),
            PolicyKind::Random => Box::new(random::RandomPolicy),
            PolicyKind::Jsq => Box::new(jsq::Jsq),
            PolicyKind::LoadBalance => Box::new(load_balance::LoadBalance),
            PolicyKind::Opt => Box::new(opt::OptPolicy::new()),
            PolicyKind::Myopic => Box::new(myopic::Myopic),
        }
    }

    /// The five §5 two-type policies (Figs. 4–7, 15–16).
    pub fn five_two_type() -> [PolicyKind; 5] {
        [
            PolicyKind::Cab,
            PolicyKind::BestFit,
            PolicyKind::Random,
            PolicyKind::Jsq,
            PolicyKind::LoadBalance,
        ]
    }

    /// The six §6 multi-type policies (Figs. 9–12).
    pub fn six_multi_type() -> [PolicyKind; 6] {
        [
            PolicyKind::GrIn,
            PolicyKind::BestFit,
            PolicyKind::Random,
            PolicyKind::Jsq,
            PolicyKind::LoadBalance,
            PolicyKind::Opt,
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_and_build_all() {
        for kind in [
            PolicyKind::Cab,
            PolicyKind::GrIn,
            PolicyKind::BestFit,
            PolicyKind::Random,
            PolicyKind::Jsq,
            PolicyKind::LoadBalance,
            PolicyKind::Opt,
            PolicyKind::Myopic,
        ] {
            let parsed = PolicyKind::parse(kind.name()).unwrap();
            assert_eq!(parsed, kind);
            let p = kind.build();
            assert_eq!(p.name(), kind.name());
        }
        assert!(PolicyKind::parse("fifo").is_err());
    }
}
