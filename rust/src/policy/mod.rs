//! Task scheduling policies (§3, §5).
//!
//! The policy answers one question on every task arrival: *which processor
//! gets the next task of type i?*  The simulator, the platform emulator
//! and the serving coordinator all drive the same [`Policy`] trait, so a
//! policy validated in simulation runs unmodified on the live system —
//! exactly the paper's methodology (§5 simulation → §7 platform).
//!
//! Implementations:
//!
//! * [`cab`] — the optimal two-type policy (Lemma 4 / Table 1).
//! * [`grin`] — the GrIn heuristic (Algorithms 1–2) for any k×l.
//! * [`best_fit`], [`random`], [`jsq`], [`load_balance`] — the §5
//!   baselines.
//! * [`opt`] — exhaustive-search oracle ("Opt" in Figs. 9–12).
//! * [`target`] — shared deficit-steering machinery for all state-target
//!   policies (CAB / GrIn / Opt).

// srclint: allow-file(index-reachable) — dispatch tables are sized by the policy's own device set

pub mod best_fit;
pub mod cab;
pub mod grin;
pub mod jsq;
pub mod myopic;
pub mod load_balance;
pub mod opt;
pub mod random;
pub mod target;

use crate::error::{Error, Result};
use crate::model::affinity::AffinityMatrix;
use crate::model::objective::{Objective, PowerProfile};
use crate::model::state::StateMatrix;
use crate::sim::rng::Rng;

/// Snapshot of the system handed to a policy at dispatch time.
#[derive(Debug)]
pub struct SystemView<'a> {
    /// Affinity matrix μ.
    pub mu: &'a AffinityMatrix,
    /// Current task distribution (the departing task already removed).
    pub state: &'a StateMatrix,
    /// Remaining work per processor in drain-time units (perfect
    /// information, as granted to LB in §5).
    pub work: &'a [f64],
    /// Per-type populations N_i.
    pub populations: &'a [u32],
}

/// Everything a solve needs, in one request: the (estimated) affinity
/// matrix and populations, the scheduling [`Objective`] with its
/// [`PowerProfile`], optional per-cell priority weights, and an optional
/// occupancy snapshot to warm-start from (the adaptive re-solve path).
///
/// This is the single argument of [`Policy::prepare`] — the former
/// `prepare`/`prepare_weighted` pair collapsed into one surface, so a
/// new solve axis extends this struct instead of growing a third trait
/// hook.
#[derive(Debug, Clone, Copy)]
pub struct SolveRequest<'a> {
    /// Affinity matrix μ (or the μ̂ estimate on adaptive paths).
    pub mu: &'a AffinityMatrix,
    /// Per-type populations N_i.
    pub populations: &'a [u32],
    /// What the solve optimizes (default [`Objective::Throughput`]).
    pub objective: Objective,
    /// Power model backing the energy objectives (ignored under
    /// [`Objective::Throughput`]).
    pub power: PowerProfile,
    /// Per-cell steering weights, row-major k×l (priority × estimate
    /// confidence — see [`grin::priority_weights`]); empty = unweighted.
    pub weights: &'a [f64],
    /// Occupancy snapshot to warm-start the solve from; None = solve
    /// from scratch.
    pub start: Option<&'a StateMatrix>,
}

impl<'a> SolveRequest<'a> {
    /// Baseline request: throughput objective, default power model, no
    /// weights, no snapshot — the exact pre-redesign `prepare(mu, pops)`.
    pub fn new(mu: &'a AffinityMatrix, populations: &'a [u32]) -> Self {
        Self {
            mu,
            populations,
            objective: Objective::Throughput,
            power: PowerProfile::default(),
            weights: &[],
            start: None,
        }
    }

    /// Builder: solve for `objective` under `power`.
    pub fn with_objective(mut self, objective: Objective, power: PowerProfile) -> Self {
        self.objective = objective;
        self.power = power;
        self
    }

    /// Builder: attach per-cell priority weights.
    pub fn with_weights(mut self, weights: &'a [f64]) -> Self {
        self.weights = weights;
        self
    }

    /// Builder: warm-start from an occupancy snapshot.
    pub fn with_start(mut self, start: &'a StateMatrix) -> Self {
        self.start = Some(start);
        self
    }

    /// Are the weights absent or uniform (i.e. the request reduces to an
    /// unweighted solve)?
    pub fn weights_trivial(&self) -> bool {
        self.weights.is_empty()
            || self.weights.windows(2).all(|w| (w[0] - w[1]).abs() <= 1e-12)
    }

    /// Guard for objective-/weight-blind policies: validate the weight
    /// shape, then reject any request this policy cannot honor — a
    /// priority- or energy-configured run on such a policy fails loudly
    /// instead of silently solving the wrong problem.  GrIn never calls
    /// this; it handles every objective and weighting.
    pub fn ensure_baseline(&self, policy_name: &str) -> Result<()> {
        if !self.weights.is_empty()
            && self.weights.len() != self.mu.types() * self.mu.procs()
        {
            return Err(Error::Shape(format!(
                "{} weights for a {}×{} system",
                self.weights.len(),
                self.mu.types(),
                self.mu.procs()
            )));
        }
        if !self.weights_trivial() {
            return Err(Error::Config(format!(
                "policy {policy_name} does not support priority weights (use grin)"
            )));
        }
        if !self.objective.is_throughput() {
            return Err(Error::Config(format!(
                "policy {policy_name} does not support objective '{}' (use grin)",
                self.objective.name()
            )));
        }
        Ok(())
    }
}

/// What a [`Policy::prepare`] solve produced: the target state the
/// policy will steer toward (None for stateless policies) and the
/// solver's objective value at that target (X, E[ℰ], EDP or X/𝒫,
/// matching the request's objective).
#[derive(Debug, Clone, Default)]
pub struct PreparedTarget {
    /// The solved target state S_max (None: nothing to steer toward).
    pub target: Option<StateMatrix>,
    /// Objective magnitude at the target (None: no solve happened).
    pub objective_value: Option<f64>,
}

/// A task-to-processor dispatch policy.
pub trait Policy: Send {
    /// Display name (figure legends).
    fn name(&self) -> &'static str;

    /// Called once before a run (and again on every re-solve) with the
    /// full [`SolveRequest`]; state-target policies solve for their
    /// target here and report it back.  The default — for stateless
    /// baselines — accepts only baseline requests (throughput objective,
    /// no effective weights; see [`SolveRequest::ensure_baseline`]) and
    /// returns an empty [`PreparedTarget`].
    fn prepare(&mut self, req: &SolveRequest<'_>) -> Result<PreparedTarget> {
        req.ensure_baseline(self.name())?;
        Ok(PreparedTarget::default())
    }

    /// Does this policy read `SystemView::work`?  The engine skips the
    /// O(N) remaining-work scan on every dispatch when it doesn't —
    /// a §Perf optimization worth ~2× simulator throughput.
    fn needs_work_estimate(&self) -> bool {
        false
    }

    /// Choose the processor for an arriving task of type `ttype`.
    fn dispatch(&mut self, ttype: usize, view: &SystemView<'_>, rng: &mut Rng) -> usize;
}

/// The policy suite of the paper's figures.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PolicyKind {
    /// CAB (two-type optimal).
    Cab,
    /// GrIn (general near-optimal).
    GrIn,
    /// Best Fit.
    BestFit,
    /// Random.
    Random,
    /// Join-the-Shortest-Queue.
    Jsq,
    /// Load Balancing with perfect information.
    LoadBalance,
    /// Exhaustive-search oracle.
    Opt,
    /// Myopic one-step-lookahead (Ahn et al. [22]; ablation baseline).
    Myopic,
}

impl PolicyKind {
    /// Parse a CLI name.
    pub fn parse(name: &str) -> Result<Self> {
        match name.to_ascii_lowercase().as_str() {
            "cab" => Ok(PolicyKind::Cab),
            "grin" => Ok(PolicyKind::GrIn),
            "bf" | "best_fit" | "bestfit" => Ok(PolicyKind::BestFit),
            "rd" | "random" => Ok(PolicyKind::Random),
            "jsq" => Ok(PolicyKind::Jsq),
            "lb" | "load_balance" => Ok(PolicyKind::LoadBalance),
            "opt" | "exhaustive" => Ok(PolicyKind::Opt),
            "myopic" => Ok(PolicyKind::Myopic),
            other => Err(Error::Parse(format!(
                "unknown policy '{other}' (cab|grin|bf|rd|jsq|lb|opt)"
            ))),
        }
    }

    /// Canonical name.
    pub fn name(self) -> &'static str {
        match self {
            PolicyKind::Cab => "CAB",
            PolicyKind::GrIn => "GrIn",
            PolicyKind::BestFit => "BF",
            PolicyKind::Random => "RD",
            PolicyKind::Jsq => "JSQ",
            PolicyKind::LoadBalance => "LB",
            PolicyKind::Opt => "Opt",
            PolicyKind::Myopic => "Myopic",
        }
    }

    /// Instantiate.
    pub fn build(self) -> Box<dyn Policy> {
        match self {
            PolicyKind::Cab => Box::new(cab::Cab::new()),
            PolicyKind::GrIn => Box::new(grin::GrInPolicy::new()),
            PolicyKind::BestFit => Box::new(best_fit::BestFit),
            PolicyKind::Random => Box::new(random::RandomPolicy),
            PolicyKind::Jsq => Box::new(jsq::Jsq),
            PolicyKind::LoadBalance => Box::new(load_balance::LoadBalance),
            PolicyKind::Opt => Box::new(opt::OptPolicy::new()),
            PolicyKind::Myopic => Box::new(myopic::Myopic),
        }
    }

    /// The five §5 two-type policies (Figs. 4–7, 15–16).
    pub fn five_two_type() -> [PolicyKind; 5] {
        [
            PolicyKind::Cab,
            PolicyKind::BestFit,
            PolicyKind::Random,
            PolicyKind::Jsq,
            PolicyKind::LoadBalance,
        ]
    }

    /// The six §6 multi-type policies (Figs. 9–12).
    pub fn six_multi_type() -> [PolicyKind; 6] {
        [
            PolicyKind::GrIn,
            PolicyKind::BestFit,
            PolicyKind::Random,
            PolicyKind::Jsq,
            PolicyKind::LoadBalance,
            PolicyKind::Opt,
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_and_build_all() {
        for kind in [
            PolicyKind::Cab,
            PolicyKind::GrIn,
            PolicyKind::BestFit,
            PolicyKind::Random,
            PolicyKind::Jsq,
            PolicyKind::LoadBalance,
            PolicyKind::Opt,
            PolicyKind::Myopic,
        ] {
            let parsed = PolicyKind::parse(kind.name()).unwrap();
            assert_eq!(parsed, kind);
            let p = kind.build();
            assert_eq!(p.name(), kind.name());
        }
        assert!(PolicyKind::parse("fifo").is_err());
    }

    #[test]
    fn default_prepare_rejects_non_baseline_requests() {
        let mu = AffinityMatrix::two_type(20.0, 15.0, 3.0, 8.0).unwrap();
        let pops = [4u32, 4];
        let mut lb = PolicyKind::LoadBalance.build();
        // Baseline and uniform-weight requests pass (uniform weights
        // reduce to the unweighted solve, the documented contract).
        assert!(lb.prepare(&SolveRequest::new(&mu, &pops)).is_ok());
        let uniform = [2.0; 4];
        assert!(lb
            .prepare(&SolveRequest::new(&mu, &pops).with_weights(&uniform))
            .is_ok());
        // Wrong-shape weights → Shape error, even when uniform.
        let bad = [1.0, 1.0, 1.0];
        assert!(lb.prepare(&SolveRequest::new(&mu, &pops).with_weights(&bad)).is_err());
        // Non-trivial weights and energy objectives fail loudly on a
        // weight-/objective-blind policy …
        let w = [2.0, 1.0, 1.0, 1.0];
        assert!(lb.prepare(&SolveRequest::new(&mu, &pops).with_weights(&w)).is_err());
        assert!(lb
            .prepare(
                &SolveRequest::new(&mu, &pops)
                    .with_objective(Objective::EnergyPerTask, PowerProfile::default())
            )
            .is_err());
        // … while GrIn honors both.
        let mut grin = PolicyKind::GrIn.build();
        assert!(grin
            .prepare(
                &SolveRequest::new(&mu, &pops)
                    .with_objective(Objective::Edp, PowerProfile::default())
            )
            .is_ok());
        assert!(grin.prepare(&SolveRequest::new(&mu, &pops).with_weights(&w)).is_ok());
    }
}
