//! Opt: exhaustive-search oracle as a dispatch policy (Figs. 9–12).
//!
//! Solves the integer program exactly at `prepare` time (exponential — use
//! only at oracle scale, as the paper does) and deficit-steers to the
//! optimum thereafter.

use super::target::TargetSteering;
use super::{Policy, PreparedTarget, SolveRequest, SystemView};
use crate::error::Result;
use crate::model::affinity::AffinityMatrix;
use crate::sim::rng::Rng;
use crate::solver::exhaustive::{ExhaustiveSolver, OptSolution};

/// The exhaustive oracle policy.
#[derive(Debug, Default)]
pub struct OptPolicy {
    steering: Option<TargetSteering>,
    solution: Option<OptSolution>,
}

impl OptPolicy {
    /// New, unprepared policy.
    pub fn new() -> Self {
        Self::default()
    }

    /// The exact optimum (after `prepare`).
    pub fn solution(&self) -> Option<&OptSolution> {
        self.solution.as_ref()
    }
}

impl Policy for OptPolicy {
    fn name(&self) -> &'static str {
        "Opt"
    }

    /// Opt is objective- and weight-blind (the oracle enumerates the
    /// throughput surface only); non-baseline requests fail loudly.
    fn prepare(&mut self, req: &SolveRequest<'_>) -> Result<PreparedTarget> {
        req.ensure_baseline(self.name())?;
        let sol = ExhaustiveSolver.solve(req.mu, req.populations)?;
        self.steering = Some(TargetSteering::new(sol.state.clone()));
        let target = sol.state.clone();
        let x = sol.throughput;
        self.solution = Some(sol);
        Ok(PreparedTarget { target: Some(target), objective_value: Some(x) })
    }

    fn dispatch(&mut self, ttype: usize, view: &SystemView<'_>, _rng: &mut Rng) -> usize {
        self.steering
            .as_ref()
            // srclint: allow(panic-reachable) — dispatch is specified to follow prepare(); violating that is a caller bug worth a loud stop
            .expect("OptPolicy::prepare must be called before dispatch")
            .dispatch(ttype, view)
            // srclint: allow(panic-reachable) — steering spans the full fleet, so some device always matches
            .expect("steering over the full fleet always yields a device")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::state::StateMatrix;
    use crate::model::throughput::x_of_state;
    use crate::policy::grin;

    #[test]
    fn opt_dominates_grin() {
        let mu = AffinityMatrix::from_rows(&[
            vec![4.0, 9.0, 2.0],
            vec![8.0, 3.0, 7.0],
            vec![1.0, 5.0, 6.0],
        ])
        .unwrap();
        let pops = [4u32, 5, 3];
        let mut p = OptPolicy::new();
        p.prepare(&SolveRequest::new(&mu, &pops)).unwrap();
        let opt_x = p.solution().unwrap().throughput;
        let grin_x = grin::solve(&mu, &pops).unwrap().throughput;
        assert!(opt_x >= grin_x - 1e-12);
    }

    #[test]
    fn steers_back_to_optimum() {
        let mu = AffinityMatrix::two_type(20.0, 15.0, 3.0, 8.0).unwrap();
        let pops = [5u32, 5];
        let mut p = OptPolicy::new();
        p.prepare(&SolveRequest::new(&mu, &pops)).unwrap();
        let target = p.solution().unwrap().state.clone();
        let mut state = target.clone();
        state.dec(0, 0).unwrap();
        let work = vec![0.0; 2];
        let view = SystemView { mu: &mu, state: &state, work: &work, populations: &pops };
        let j = p.dispatch(0, &view, &mut Rng::new(0));
        state.inc(0, j);
        assert_eq!(x_of_state(&mu, &state), x_of_state(&mu, &target));
        assert_eq!(state, target);
    }

    #[test]
    fn optimum_is_truly_exhaustive_on_small_grid() {
        let mu = AffinityMatrix::two_type(9.0, 5.0, 2.0, 7.0).unwrap();
        let pops = [3u32, 3];
        let mut p = OptPolicy::new();
        p.prepare(&SolveRequest::new(&mu, &pops)).unwrap();
        let best = p.solution().unwrap().throughput;
        for n11 in 0..=3 {
            for n22 in 0..=3 {
                let s = StateMatrix::from_two_type(n11, n22, 3, 3).unwrap();
                assert!(x_of_state(&mu, &s) <= best + 1e-12);
            }
        }
    }
}
