//! GrIn — the Greedy-Increase heuristic (§4.2, Algorithms 1–2).
//!
//! Solves the integer program (Eqs. 28–29) for any k task types × l
//! processor types in near-linear time per move:
//!
//! 1. **Init** (Algorithm 1): the "max j-col μ" seeding — each column's
//!    fastest task type claims it; rows with several claimed columns
//!    spread one task to each and dump the remainder on the slowest
//!    claimed column; rows with none go to their best-fit column and are
//!    immediately locally optimized.
//! 2. **Greedy increase** (Algorithm 2 + Lemma 8): repeatedly move one
//!    task of some type p from the processor where removal costs least
//!    (max X_df−, Eq. 36) to the processor where insertion gains most
//!    (max X_df+, Eq. 34); every accepted move strictly increases X_sys,
//!    so the loop terminates at a local maximum (measured within 1.6% of
//!    the exhaustive optimum over 1000 random systems — see
//!    `benches/fig9_12_multitype.rs --gap`).

// srclint: allow-file(index-reachable) — the GrIn allocation matrix is k by l, fixed by the solve inputs

use super::target::TargetSteering;
use super::{Policy, PreparedTarget, SolveRequest, SystemView};
use crate::error::{Error, Result};
use crate::model::affinity::AffinityMatrix;
use crate::model::objective::{Objective, ObjectiveEval, PowerProfile};
use crate::model::state::StateMatrix;
use crate::model::throughput::{x_df_minus, x_df_plus, x_of_state, IncrementalX};
use crate::sim::rng::Rng;

/// Outcome of a GrIn solve.
#[derive(Debug, Clone)]
pub struct GrInSolution {
    /// The locally optimal task distribution.
    pub state: StateMatrix,
    /// X_sys at that state (Eq. 28).
    pub throughput: f64,
    /// Number of greedy moves performed after initialization.
    pub moves: usize,
}

/// Strictly-positive gain threshold: guarantees termination (Lemma 8's
/// monotone increase) in the presence of floating-point noise.
const GAIN_EPS: f64 = 1e-12;

/// Algorithm 1: initial task distribution.
pub fn initialize(mu: &AffinityMatrix, populations: &[u32]) -> Result<StateMatrix> {
    let (k, l) = (mu.types(), mu.procs());
    if populations.len() != k {
        return Err(Error::Shape(format!(
            "{} populations for {k} task types",
            populations.len()
        )));
    }
    let mut n = StateMatrix::zeros(k, l);

    // The 0-1 "max μ" matrix 𝔘: claimed[j] = row that owns column j.
    let claimed: Vec<usize> = (0..l).map(|j| mu.max_col_row(j)).collect();

    for row in 0..k {
        let ni = populations[row];
        let mut cols: Vec<usize> =
            (0..l).filter(|&j| claimed[j] == row).collect();
        match cols.len() {
            0 => {
                // No claimed column: best-fit, then local re-distribution
                // (Algorithm 1 lines 18–21, iterated to a row-local max).
                n.set(row, mu.best_proc(row), ni);
                local_row_optimize(mu, &mut n, row);
            }
            1 => n.set(row, cols[0], ni),
            _ => {
                // Sort claimed columns by this row's rate, descending.
                cols.sort_by(|&a, &b| mu.rate(row, b).total_cmp(&mu.rate(row, a)));
                let mut left = ni;
                for &j in &cols {
                    if left == 0 {
                        break;
                    }
                    n.set(row, j, 1);
                    left -= 1;
                }
                // Remainder goes to the slowest claimed column (line 13).
                // srclint: allow(panic-reachable) — cols is non-empty: the claim loop above pushed at least one column
                let last = *cols.last().unwrap();
                n.set(row, last, n.get(row, last) + left);
            }
        }
    }
    Ok(n)
}

/// Re-distribute one row's tasks greedily until its local max (used by the
/// Algorithm-1 zero-claim case).
fn local_row_optimize(mu: &AffinityMatrix, n: &mut StateMatrix, row: usize) {
    loop {
        match best_move_for_row(mu, n, row) {
            Some((from, to, gain)) if gain > GAIN_EPS => {
                // srclint: allow(panic-reachable) — best_move_for_row only proposes moves out of cells it counted as occupied
                n.move_task(row, from, to).expect("move from counted cell");
            }
            _ => break,
        }
    }
}

/// The best single move for `row`: returns (from, to, exact ΔX).
fn best_move_for_row(
    mu: &AffinityMatrix,
    n: &StateMatrix,
    row: usize,
) -> Option<(usize, usize, f64)> {
    let l = mu.procs();
    // Best insertion target (Eq. 34) and best removal source (Eq. 36).
    let mut best: Option<(usize, usize, f64)> = None;
    for from in 0..l {
        if n.get(row, from) == 0 {
            continue;
        }
        let dfm = x_df_minus(mu, n, row, from);
        for to in 0..l {
            if to == from {
                continue;
            }
            // Columns are independent ⇒ the combined delta is exact.
            let gain = dfm + x_df_plus(mu, n, row, to);
            if best.map_or(true, |(_, _, g)| gain > g) {
                best = Some((from, to, gain));
            }
        }
    }
    best
}

/// The best single move for `row` against the cached column sums:
/// two contiguous O(l) delta passes (SIMD-friendly, see
/// [`IncrementalX::delta_plus_row`]) followed by an O(l²) combine over
/// the precomputed buffers — instead of the O(l²·k) scans of
/// [`best_move_for_row`].  `dplus`/`dminus` are caller-owned scratch so
/// the greedy loop allocates nothing per move.
fn best_move_for_row_inc(
    inc: &IncrementalX,
    n: &StateMatrix,
    row: usize,
    dplus: &mut [f64],
    dminus: &mut [f64],
) -> Option<(usize, usize, f64)> {
    let l = inc.procs();
    inc.delta_plus_row(row, dplus);
    inc.delta_minus_row(row, dminus);
    let mut best: Option<(usize, usize, f64)> = None;
    for from in 0..l {
        if n.get(row, from) == 0 {
            continue;
        }
        let dfm = dminus[from];
        for to in 0..l {
            if to == from {
                continue;
            }
            // Columns are independent ⇒ the combined delta is exact.
            let gain = dfm + dplus[to];
            if best.map_or(true, |(_, _, g)| gain > g) {
                best = Some((from, to, gain));
            }
        }
    }
    best
}

/// Algorithm 2: full GrIn solve.
///
/// The greedy loop runs against the [`IncrementalX`] caches, so each
/// accepted move costs O(state-delta) — two column updates — and each
/// probe is O(1); the solution is identical to evaluating Eqs. 34/36 in
/// full (`tests/adaptive_e2e.rs` property-checks the equivalence).
pub fn solve(mu: &AffinityMatrix, populations: &[u32]) -> Result<GrInSolution> {
    let n = initialize(mu, populations)?;
    greedy_increase(mu, n, populations)
}

/// Batched re-solve entry point for the sharded coordinator: run the
/// greedy-increase loop from a gathered occupancy snapshot instead of
/// the Algorithm-1 seeding.
///
/// The global coordinator assembles per-shard μ̂/occupancy snapshots
/// into one k×l view and warm-starts GrIn from the fleet's *current*
/// distribution — under mild drift the snapshot is already near the new
/// local maximum, so the batched solve converges in a handful of moves
/// (`GrInSolution::moves` is the metric) where a cold solve replays the
/// whole seeding.  `start` must satisfy `populations`
/// ([`crate::model::state::StateMatrix::check_populations`]); gather-time
/// in-flight skew is the caller's to project out.
pub fn solve_from_snapshot(
    mu: &AffinityMatrix,
    populations: &[u32],
    start: &StateMatrix,
) -> Result<GrInSolution> {
    if start.types() != mu.types() || start.procs() != mu.procs() {
        return Err(Error::Shape(format!(
            "snapshot is {}×{}, μ is {}×{}",
            start.types(),
            start.procs(),
            mu.types(),
            mu.procs()
        )));
    }
    start.check_populations(populations)?;
    greedy_increase(mu, start.clone(), populations)
}

/// Is this priority vector trivial — empty or all-equal?  A trivial
/// vector means every class has the same standing, so the whole
/// weighted pipeline (solve *and* steering) reduces to the plain
/// unweighted paths: that keeps the documented equal-priorities ≡
/// unweighted contract exact end to end, avoids injecting
/// estimator-confidence jitter into runs that asked for no
/// prioritization, and keeps weight-blind policies usable under an
/// all-equal vector.
pub fn trivial_priorities(priorities: &[u32]) -> bool {
    priorities.windows(2).all(|w| w[0] == w[1])
}

/// Assemble the per-cell steering/solve weights of the priority
/// subsystem: normalized class priority × estimate-confidence discount.
///
/// * `priorities[i] ≥ 1` is the integer priority of class i, normalized
///   to mean 1 across classes so that equal priorities — whatever their
///   absolute value — produce the all-ones vector and the weighted
///   solve degenerates to the unweighted one *exactly*.
/// * `confidence[i·l + j] ∈ [0, 1]` is how much the estimator trusts
///   cell (i, j) right now ([`crate::coordinator::RateEstimator::confidence`];
///   pass 1.0 everywhere on oracle paths).  It is mapped to the discount
///   (1 + c)/2 ∈ [½, 1], so a cold cell halves a class's claim on that
///   device instead of zeroing it (a zero weight would make the solve
///   degenerate).
pub fn priority_weights(
    priorities: &[u32],
    confidence: &[f64],
    procs: usize,
) -> Result<Vec<f64>> {
    let k = priorities.len();
    if k == 0 {
        return Err(Error::Config("priority_weights needs ≥ 1 class".into()));
    }
    if priorities.iter().any(|&p| p == 0) {
        return Err(Error::Config("class priorities must be ≥ 1".into()));
    }
    if confidence.len() != k * procs {
        return Err(Error::Shape(format!(
            "{} confidence cells for a {k}×{procs} system",
            confidence.len()
        )));
    }
    if confidence.iter().any(|&c| !(0.0..=1.0).contains(&c)) {
        return Err(Error::Config("confidence must lie in [0, 1]".into()));
    }
    let mean = priorities.iter().map(|&p| p as f64).sum::<f64>() / k as f64;
    Ok((0..k)
        .flat_map(|i| {
            let pri = priorities[i] as f64 / mean;
            (0..procs).map(move |j| pri * (1.0 + confidence[i * procs + j]) / 2.0)
        })
        .collect())
}

/// Priority-weighted GrIn solve: run Algorithms 1–2 against the
/// weighted objective Xw(S)
/// ([`crate::model::throughput::WeightedIncrementalX`] — structurally
/// the unweighted greedy loop over the element-wise product w ∘ μ), so
/// a high-priority class claims its fast devices even when that costs a
/// little total throughput.  `GrInSolution::throughput` reports the
/// *true* (unweighted) X at the solved state, so weighted and
/// unweighted solves are directly comparable; with a uniform weight
/// vector the result is identical to [`solve`].
pub fn solve_weighted(
    mu: &AffinityMatrix,
    populations: &[u32],
    weights: &[f64],
) -> Result<GrInSolution> {
    let scaled = mu.scaled(weights)?;
    let sol = solve(&scaled, populations)?;
    let throughput = x_of_state(mu, &sol.state);
    Ok(GrInSolution { state: sol.state, throughput, moves: sol.moves })
}

/// Weighted sibling of [`solve_from_snapshot`]: warm-start the weighted
/// greedy loop from a gathered occupancy snapshot (the sharded plane's
/// batched re-solve under priorities).  As with [`solve_weighted`], the
/// reported throughput is the true X at the solved state.
pub fn solve_weighted_from_snapshot(
    mu: &AffinityMatrix,
    populations: &[u32],
    weights: &[f64],
    start: &StateMatrix,
) -> Result<GrInSolution> {
    let scaled = mu.scaled(weights)?;
    let sol = solve_from_snapshot(&scaled, populations, start)?;
    let throughput = x_of_state(mu, &sol.state);
    Ok(GrInSolution { state: sol.state, throughput, moves: sol.moves })
}

/// Dispatch a full [`SolveRequest`] to the matching GrIn solve: plain,
/// weighted, objective-scored, cold or warm-started.  This is the one
/// entry point behind [`GrInPolicy::prepare`] — GrIn honors every
/// request shape except the (so far undefined) combination of priority
/// weights with a non-throughput objective, which errors loudly.
pub fn solve_request(req: &SolveRequest<'_>) -> Result<GrInSolution> {
    if !req.weights.is_empty()
        && req.weights.len() != req.mu.types() * req.mu.procs()
    {
        return Err(Error::Shape(format!(
            "{} weights for a {}×{} system",
            req.weights.len(),
            req.mu.types(),
            req.mu.procs()
        )));
    }
    match (req.weights_trivial(), req.objective.is_throughput()) {
        (true, true) => match req.start {
            Some(s) => solve_from_snapshot(req.mu, req.populations, s),
            None => solve(req.mu, req.populations),
        },
        (false, true) => match req.start {
            Some(s) => {
                solve_weighted_from_snapshot(req.mu, req.populations, req.weights, s)
            }
            None => solve_weighted(req.mu, req.populations, req.weights),
        },
        (true, false) => match req.start {
            Some(s) => solve_objective_from_snapshot(
                req.mu,
                req.populations,
                req.objective,
                &req.power,
                s,
            ),
            None => {
                solve_objective(req.mu, req.populations, req.objective, &req.power)
            }
        },
        (false, false) => Err(Error::Config(
            "priority weights combine only with the throughput objective".into(),
        )),
    }
}

/// Objective-scored GrIn solve: run the throughput solve first (it
/// yields the unconstrained optimum X*, the
/// [`Objective::ThroughputPerWatt`] reference), then descend the
/// energy/EDP/perf-per-watt surface with the same greedy move loop,
/// scored by [`ObjectiveEval`] instead of raw ΔX.
/// `GrInSolution::throughput` reports the true X at the solved state,
/// directly comparable across objectives.
pub fn solve_objective(
    mu: &AffinityMatrix,
    populations: &[u32],
    objective: Objective,
    power: &PowerProfile,
) -> Result<GrInSolution> {
    if objective.is_throughput() {
        return solve(mu, populations);
    }
    let base = solve(mu, populations)?;
    greedy_objective(mu, base.state, populations, objective, power, base.throughput)
}

/// Warm-started sibling of [`solve_objective`] (the adaptive/sharded
/// re-solve path).  [`Objective::ThroughputPerWatt`] ignores the
/// snapshot and re-solves cold: its feasibility floor references the
/// unconstrained optimum X*, and an arbitrary snapshot may sit below
/// the floor with no single feasible move back inside — the cold path
/// starts at X* and is feasible by construction.
pub fn solve_objective_from_snapshot(
    mu: &AffinityMatrix,
    populations: &[u32],
    objective: Objective,
    power: &PowerProfile,
    start: &StateMatrix,
) -> Result<GrInSolution> {
    if objective.is_throughput() {
        return solve_from_snapshot(mu, populations, start);
    }
    if matches!(objective, Objective::ThroughputPerWatt { .. }) {
        return solve_objective(mu, populations, objective, power);
    }
    if start.types() != mu.types() || start.procs() != mu.procs() {
        return Err(Error::Shape(format!(
            "snapshot is {}×{}, μ is {}×{}",
            start.types(),
            start.procs(),
            mu.types(),
            mu.procs()
        )));
    }
    start.check_populations(populations)?;
    greedy_objective(mu, start.clone(), populations, objective, power, 0.0)
}

/// The objective-scored greedy loop (shared by [`solve_objective`] and
/// [`solve_objective_from_snapshot`]): identical structure to
/// [`greedy_increase`], but each candidate move is probed through
/// [`ObjectiveEval::probe`] (O(1) given the cached base pair) and
/// accepted on objective-score gain, subject to the
/// [`ObjectiveEval::feasible`] throughput floor.  Every accepted move
/// strictly increases the score, so the loop terminates at a local
/// optimum of the requested objective.
fn greedy_objective(
    mu: &AffinityMatrix,
    mut n: StateMatrix,
    populations: &[u32],
    objective: Objective,
    power: &PowerProfile,
    x_ref: f64,
) -> Result<GrInSolution> {
    let (k, l) = (mu.types(), mu.procs());
    let mut eval = ObjectiveEval::new(mu, &n, power, objective, x_ref)?;
    let mut moves = 0usize;
    // Same hard cap as the throughput loop: monotone score increase
    // guarantees termination, but guard regardless.
    let cap = 64 + (populations.iter().sum::<u32>() as usize) * l * k * 4;
    loop {
        let mut improved = false;
        for row in 0..k {
            let base = eval.base();
            let score0 = eval.score_of(base.0, base.1);
            let mut best: Option<(usize, usize, f64)> = None;
            for from in 0..l {
                if n.get(row, from) == 0 {
                    continue;
                }
                for to in 0..l {
                    if to == from {
                        continue;
                    }
                    let (x2, p2) = eval.probe(row, from, to, base);
                    if !eval.feasible(x2) {
                        continue;
                    }
                    let gain = eval.score_of(x2, p2) - score0;
                    if gain > GAIN_EPS && best.map_or(true, |(_, _, g)| gain > g) {
                        best = Some((from, to, gain));
                    }
                }
            }
            if let Some((from, to, _)) = best {
                n.move_task(row, from, to)?;
                eval.apply_move(row, from, to);
                moves += 1;
                improved = true;
            }
        }
        if !improved || moves >= cap {
            break;
        }
    }
    let throughput = x_of_state(mu, &n);
    n.check_populations(populations)?;
    Ok(GrInSolution { state: n, throughput, moves })
}

/// The Algorithm-2 greedy loop from an arbitrary feasible start state
/// (shared by [`solve`] and [`solve_from_snapshot`]).
fn greedy_increase(
    mu: &AffinityMatrix,
    mut n: StateMatrix,
    populations: &[u32],
) -> Result<GrInSolution> {
    let (k, l) = (mu.types(), mu.procs());
    let mut inc = IncrementalX::new(mu, &n);
    // Scratch for the per-row delta passes, allocated once per solve.
    let mut dplus = vec![0.0f64; l];
    let mut dminus = vec![0.0f64; l];
    let mut moves = 0usize;
    // Hard cap: each move strictly increases X_sys, but guard regardless.
    let cap = 64 + (populations.iter().sum::<u32>() as usize) * l * k * 4;
    loop {
        let mut improved = false;
        for row in 0..k {
            if let Some((from, to, gain)) =
                best_move_for_row_inc(&inc, &n, row, &mut dplus, &mut dminus)
            {
                if gain > GAIN_EPS {
                    n.move_task(row, from, to)?;
                    inc.apply_move(row, from, to);
                    moves += 1;
                    improved = true;
                }
            }
        }
        if !improved || moves >= cap {
            break;
        }
    }
    let throughput = x_of_state(mu, &n);
    n.check_populations(populations)?;
    Ok(GrInSolution { state: n, throughput, moves })
}

/// GrIn as a dispatch policy: solve once, then deficit-steer to the
/// solution state.
#[derive(Debug, Default)]
pub struct GrInPolicy {
    steering: Option<TargetSteering>,
    solution: Option<GrInSolution>,
}

impl GrInPolicy {
    /// New, unprepared policy.
    pub fn new() -> Self {
        Self::default()
    }

    /// The solved target (after `prepare`).
    pub fn solution(&self) -> Option<&GrInSolution> {
        self.solution.as_ref()
    }
}

impl Policy for GrInPolicy {
    fn name(&self) -> &'static str {
        "GrIn"
    }

    /// GrIn honors the full [`SolveRequest`] surface: plain, weighted
    /// and objective-scored solves, cold or warm-started (see
    /// [`solve_request`]).  Steering carries the request's weights when
    /// they are effective, so target and weight vector swap as one unit.
    fn prepare(&mut self, req: &SolveRequest<'_>) -> Result<PreparedTarget> {
        let sol = solve_request(req)?;
        let objective_value = if req.objective.is_throughput() {
            sol.throughput
        } else {
            ObjectiveEval::new(req.mu, &sol.state, &req.power, req.objective, sol.throughput)?
                .objective_value()
        };
        self.steering = Some(if req.weights_trivial() {
            TargetSteering::new(sol.state.clone())
        } else {
            TargetSteering::with_weights(sol.state.clone(), req.weights.to_vec())
        });
        let target = sol.state.clone();
        self.solution = Some(sol);
        Ok(PreparedTarget { target: Some(target), objective_value: Some(objective_value) })
    }

    fn dispatch(&mut self, ttype: usize, view: &SystemView<'_>, _rng: &mut Rng) -> usize {
        self.steering
            .as_ref()
            // srclint: allow(panic-reachable) — dispatch is specified to follow prepare(); violating that is a caller bug worth a loud stop
            .expect("GrInPolicy::prepare must be called before dispatch")
            .dispatch(ttype, view)
            // srclint: allow(panic-reachable) — steering spans the full fleet, so some device always matches
            .expect("steering over the full fleet always yields a device")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::rng::Rng;

    #[test]
    fn init_satisfies_populations() {
        let mu = AffinityMatrix::from_rows(&[
            vec![10.0, 2.0, 4.0],
            vec![1.0, 8.0, 3.0],
            vec![5.0, 5.0, 9.0],
        ])
        .unwrap();
        let pops = [7u32, 5, 3];
        let n = initialize(&mu, &pops).unwrap();
        n.check_populations(&pops).unwrap();
    }

    #[test]
    fn init_multi_claim_row_spreads_then_dumps() {
        // Row 0 claims both columns (it is fastest on each).
        let mu = AffinityMatrix::from_rows(&[vec![10.0, 9.0], vec![1.0, 2.0]]).unwrap();
        let n = initialize(&mu, &[5, 3]).unwrap();
        // One task to the fastest claimed column, remainder to the slowest.
        assert_eq!(n.get(0, 0), 1);
        assert_eq!(n.get(0, 1), 4);
        n.check_populations(&[5, 3]).unwrap();
    }

    #[test]
    fn solve_monotone_gain_lemma8() {
        // Every accepted move must strictly increase X_sys: verify by
        // replaying the solve move-by-move.
        let mu = AffinityMatrix::from_rows(&[
            vec![12.0, 3.0, 7.0],
            vec![2.0, 9.0, 4.0],
            vec![6.0, 6.0, 10.0],
        ])
        .unwrap();
        let pops = [8u32, 6, 4];
        let mut n = initialize(&mu, &pops).unwrap();
        let mut x = x_of_state(&mu, &n);
        for _ in 0..1000 {
            let mut moved = false;
            for row in 0..3 {
                if let Some((from, to, gain)) = best_move_for_row(&mu, &n, row) {
                    if gain > GAIN_EPS {
                        n.move_task(row, from, to).unwrap();
                        let x2 = x_of_state(&mu, &n);
                        assert!(x2 > x, "move did not increase X: {x} -> {x2}");
                        // The predicted gain is exact (column independence).
                        assert!((x2 - x - gain).abs() < 1e-9);
                        x = x2;
                        moved = true;
                    }
                }
            }
            if !moved {
                break;
            }
        }
    }

    #[test]
    fn grin_equals_cab_on_two_types() {
        // §7: "GrIn gives the same solution as CAB's analytical solution
        // in systems with two processor types."
        use crate::policy::cab::Cab;
        for (mu, pops) in [
            (AffinityMatrix::two_type(20.0, 15.0, 3.0, 8.0).unwrap(), [10u32, 10u32]),
            (AffinityMatrix::two_type(928.0, 3.61, 587.0, 2398.0).unwrap(), [6, 14]),
            (AffinityMatrix::two_type(253.0, 0.911, 587.0, 2398.0).unwrap(), [12, 8]),
        ] {
            let (_, cab_target) = Cab::target_state(&mu, &pops).unwrap();
            let grin = solve(&mu, &pops).unwrap();
            let x_cab = x_of_state(&mu, &cab_target);
            assert!(
                (grin.throughput - x_cab).abs() < 1e-9,
                "GrIn {} vs CAB {} for {mu:?}",
                grin.throughput,
                x_cab
            );
        }
    }

    #[test]
    fn solve_respects_populations_and_improves_init() {
        let mut rng = Rng::new(2024);
        for _ in 0..50 {
            let k = 2 + rng.index(3);
            let l = 2 + rng.index(3);
            let rows: Vec<Vec<f64>> = (0..k)
                .map(|_| (0..l).map(|_| rng.range_f64(0.5, 30.0)).collect())
                .collect();
            let mu = AffinityMatrix::from_rows(&rows).unwrap();
            let pops: Vec<u32> = (0..k).map(|_| 1 + rng.below(10) as u32).collect();
            let init = initialize(&mu, &pops).unwrap();
            let sol = solve(&mu, &pops).unwrap();
            sol.state.check_populations(&pops).unwrap();
            assert!(sol.throughput >= x_of_state(&mu, &init) - 1e-9);
        }
    }

    #[test]
    fn incremental_move_selection_matches_full_scan() {
        let mut rng = Rng::new(77);
        for _ in 0..40 {
            let k = 2 + rng.index(3);
            let l = 2 + rng.index(3);
            let rows: Vec<Vec<f64>> = (0..k)
                .map(|_| (0..l).map(|_| rng.range_f64(0.5, 30.0)).collect())
                .collect();
            let mu = AffinityMatrix::from_rows(&rows).unwrap();
            let pops: Vec<u32> = (0..k).map(|_| 1 + rng.below(8) as u32).collect();
            let n = initialize(&mu, &pops).unwrap();
            let inc = IncrementalX::new(&mu, &n);
            let mut dplus = vec![0.0f64; l];
            let mut dminus = vec![0.0f64; l];
            for row in 0..k {
                let full = best_move_for_row(&mu, &n, row);
                let fast = best_move_for_row_inc(&inc, &n, row, &mut dplus, &mut dminus);
                match (full, fast) {
                    (None, None) => {}
                    (Some((f1, t1, g1)), Some((f2, t2, g2))) => {
                        assert_eq!((f1, t1), (f2, t2), "row {row}");
                        assert!((g1 - g2).abs() < 1e-12, "row {row}: {g1} vs {g2}");
                    }
                    other => panic!("selection mismatch: {other:?}"),
                }
            }
        }
    }

    #[test]
    fn solve_from_snapshot_warm_starts_and_validates() {
        let mu = AffinityMatrix::from_rows(&[
            vec![12.0, 3.0, 7.0],
            vec![2.0, 9.0, 4.0],
            vec![6.0, 6.0, 10.0],
        ])
        .unwrap();
        let pops = [8u32, 6, 4];
        let cold = solve(&mu, &pops).unwrap();
        // A local maximum is a fixed point of the warm start.
        let again = solve_from_snapshot(&mu, &pops, &cold.state).unwrap();
        assert_eq!(again.moves, 0);
        assert!((again.throughput - cold.throughput).abs() < 1e-12);
        // From a deliberately bad snapshot (everything on processor 0)
        // the greedy loop climbs back to cold-solve quality.
        let mut bad = StateMatrix::zeros(3, 3);
        for (i, &p) in pops.iter().enumerate() {
            bad.set(i, 0, p);
        }
        let warm = solve_from_snapshot(&mu, &pops, &bad).unwrap();
        warm.state.check_populations(&pops).unwrap();
        assert!(warm.moves > 0);
        assert!(warm.throughput >= x_of_state(&mu, &bad));
        assert!(warm.throughput >= cold.throughput * 0.9);
        // Shape and population mismatches are rejected.
        let narrow = StateMatrix::zeros(3, 2);
        assert!(solve_from_snapshot(&mu, &pops, &narrow).is_err());
        let short = StateMatrix::zeros(3, 3);
        assert!(solve_from_snapshot(&mu, &pops, &short).is_err());
    }

    #[test]
    fn trivial_priority_vectors_are_detected() {
        assert!(trivial_priorities(&[]));
        assert!(trivial_priorities(&[3]));
        assert!(trivial_priorities(&[2, 2, 2]));
        assert!(!trivial_priorities(&[2, 1]));
        assert!(!trivial_priorities(&[1, 1, 2]));
    }

    #[test]
    fn priority_weights_normalize_and_validate() {
        // Equal priorities + full confidence ⇒ exactly all ones, any
        // absolute priority level.
        let w = priority_weights(&[3, 3], &[1.0; 4], 2).unwrap();
        assert!(w.iter().all(|&x| x == 1.0), "{w:?}");
        // Priority 4-vs-1 with mean 2.5: weights 1.6 / 0.4 at conf 1.
        let w = priority_weights(&[4, 1], &[1.0; 4], 2).unwrap();
        assert!((w[0] - 1.6).abs() < 1e-12 && (w[3] - 0.4).abs() < 1e-12);
        // Zero confidence halves a cell's claim instead of zeroing it.
        let w = priority_weights(&[2, 2], &[0.0, 1.0, 1.0, 1.0], 2).unwrap();
        assert!((w[0] - 0.5).abs() < 1e-12 && (w[1] - 1.0).abs() < 1e-12);
        assert!(priority_weights(&[], &[], 2).is_err());
        assert!(priority_weights(&[0, 1], &[1.0; 4], 2).is_err());
        assert!(priority_weights(&[1, 1], &[1.0; 3], 2).is_err());
        assert!(priority_weights(&[1, 1], &[1.0, 1.0, 2.0, 1.0], 2).is_err());
    }

    #[test]
    fn equal_priority_weighted_solve_matches_unweighted() {
        let mut rng = Rng::new(404);
        for _ in 0..30 {
            let k = 2 + rng.index(3);
            let l = 2 + rng.index(3);
            let rows: Vec<Vec<f64>> = (0..k)
                .map(|_| (0..l).map(|_| rng.range_f64(0.5, 30.0)).collect())
                .collect();
            let mu = AffinityMatrix::from_rows(&rows).unwrap();
            let pops: Vec<u32> = (0..k).map(|_| 1 + rng.below(8) as u32).collect();
            let pri = vec![1 + rng.below(5) as u32; k]; // equal across classes
            let w = priority_weights(&pri, &vec![1.0; k * l], l).unwrap();
            let plain = solve(&mu, &pops).unwrap();
            let weighted = solve_weighted(&mu, &pops, &w).unwrap();
            assert!(
                (plain.throughput - weighted.throughput).abs() < 1e-9,
                "equal-priority weighted {} vs unweighted {}",
                weighted.throughput,
                plain.throughput
            );
            assert_eq!(plain.state, weighted.state);
        }
    }

    #[test]
    fn weighted_solve_reserves_fast_device_for_high_priority() {
        // The contended-fast-device system of the priority_mix scenario:
        // both classes prefer P1; unweighted GrIn crowds the
        // low-priority majority onto it, the 4:1 weighted solve reserves
        // it for the high-priority class.
        let mu = crate::sim::workload::priority_mu();
        let pops = [4u32, 16];
        let plain = solve(&mu, &pops).unwrap();
        // Unweighted: low-priority tasks share P1 with the entire
        // high-priority class.
        assert!(plain.state.get(1, 0) > 0, "unweighted keeps P1 exclusive? {}", plain.state);
        let w = priority_weights(&[4, 1], &[1.0; 4], 2).unwrap();
        let weighted = solve_weighted(&mu, &pops, &w).unwrap();
        // Weighted: the high-priority class owns P1 outright.
        assert_eq!(weighted.state.get(0, 0), 4, "{}", weighted.state);
        assert_eq!(weighted.state.get(1, 0), 0, "{}", weighted.state);
        weighted.state.check_populations(&pops).unwrap();
        // The reservation costs a little total X — bounded, not free.
        assert!(weighted.throughput <= plain.throughput + 1e-9);
        assert!(weighted.throughput >= plain.throughput * 0.9);
        // Warm-started weighted solve agrees from the unweighted state.
        let warm = solve_weighted_from_snapshot(&mu, &pops, &w, &plain.state).unwrap();
        assert_eq!(warm.state, weighted.state);
    }

    #[test]
    fn policy_wrapper_steers_to_solution() {
        let mu = AffinityMatrix::two_type(20.0, 15.0, 3.0, 8.0).unwrap();
        let mut p = GrInPolicy::new();
        let prepared = p.prepare(&SolveRequest::new(&mu, &[4, 4])).unwrap();
        let sol_state = p.solution().unwrap().state.clone();
        assert_eq!(prepared.target.as_ref(), Some(&sol_state));
        assert!(
            (prepared.objective_value.unwrap() - p.solution().unwrap().throughput).abs()
                < 1e-12
        );
        // Remove one task and let the policy re-place it.
        let mut state = sol_state.clone();
        state.dec(1, 1).unwrap();
        let work = vec![0.0; 2];
        let view = SystemView { mu: &mu, state: &state, work: &work, populations: &[4, 4] };
        let j = p.dispatch(1, &view, &mut Rng::new(0));
        state.inc(1, j);
        assert_eq!(state, sol_state);
    }

    #[test]
    fn solve_request_routes_to_matching_solver() {
        let mu = crate::sim::workload::priority_mu();
        let pops = [4u32, 16];
        // Baseline request ≡ plain solve, bit-identical.
        let plain = solve(&mu, &pops).unwrap();
        let via_req = solve_request(&SolveRequest::new(&mu, &pops)).unwrap();
        assert_eq!(plain.state, via_req.state);
        assert_eq!(plain.throughput.to_bits(), via_req.throughput.to_bits());
        // Weighted request ≡ solve_weighted.
        let w = priority_weights(&[4, 1], &[1.0; 4], 2).unwrap();
        let weighted = solve_weighted(&mu, &pops, &w).unwrap();
        let via_req =
            solve_request(&SolveRequest::new(&mu, &pops).with_weights(&w)).unwrap();
        assert_eq!(weighted.state, via_req.state);
        // Warm-started request ≡ solve_from_snapshot.
        let warm = solve_from_snapshot(&mu, &pops, &plain.state).unwrap();
        let via_req =
            solve_request(&SolveRequest::new(&mu, &pops).with_start(&plain.state))
                .unwrap();
        assert_eq!(warm.state, via_req.state);
        // Bad weight shapes and weight×energy combinations error.
        assert!(solve_request(
            &SolveRequest::new(&mu, &pops).with_weights(&[1.0, 2.0, 3.0])
        )
        .is_err());
        assert!(solve_request(
            &SolveRequest::new(&mu, &pops)
                .with_weights(&w)
                .with_objective(Objective::EnergyPerTask, PowerProfile::default())
        )
        .is_err());
    }

    #[test]
    fn energy_solve_never_worse_than_throughput_solve_on_energy() {
        use crate::model::energy::PowerScenario;
        let mut rng = Rng::new(606);
        for _ in 0..25 {
            let k = 2 + rng.index(3);
            let l = 2 + rng.index(3);
            let rows: Vec<Vec<f64>> = (0..k)
                .map(|_| (0..l).map(|_| rng.range_f64(0.5, 30.0)).collect())
                .collect();
            let mu = AffinityMatrix::from_rows(&rows).unwrap();
            let pops: Vec<u32> = (0..k).map(|_| 1 + rng.below(8) as u32).collect();
            let power =
                PowerProfile::new(1.3, PowerScenario::Exponent(0.5)).with_idle(0.2);
            let xsol = solve(&mu, &pops).unwrap();
            for objective in [Objective::EnergyPerTask, Objective::Edp] {
                let esol = solve_objective(&mu, &pops, objective, &power).unwrap();
                esol.state.check_populations(&pops).unwrap();
                let at = |s: &StateMatrix| {
                    let ev =
                        ObjectiveEval::new(&mu, s, &power, objective, 0.0).unwrap();
                    ev.objective_value()
                };
                // The energy descent starts from the throughput solution
                // and only accepts improving moves.
                assert!(
                    at(&esol.state) <= at(&xsol.state) + 1e-9,
                    "{objective:?} solve worse than its start"
                );
            }
        }
    }

    #[test]
    fn tpw_solve_respects_throughput_floor() {
        use crate::model::energy::PowerScenario;
        let mut rng = Rng::new(909);
        for _ in 0..25 {
            let k = 2 + rng.index(3);
            let l = 2 + rng.index(3);
            let rows: Vec<Vec<f64>> = (0..k)
                .map(|_| (0..l).map(|_| rng.range_f64(0.5, 30.0)).collect())
                .collect();
            let mu = AffinityMatrix::from_rows(&rows).unwrap();
            let pops: Vec<u32> = (0..k).map(|_| 1 + rng.below(8) as u32).collect();
            let power = PowerProfile::new(1.0, PowerScenario::Exponent(0.5));
            let min_x_frac = 0.85;
            let xstar = solve(&mu, &pops).unwrap().throughput;
            let sol = solve_objective(
                &mu,
                &pops,
                Objective::ThroughputPerWatt { min_x_frac },
                &power,
            )
            .unwrap();
            assert!(
                sol.throughput >= min_x_frac * xstar - 1e-9,
                "TPW X {} below floor {}",
                sol.throughput,
                min_x_frac * xstar
            );
        }
    }
}
