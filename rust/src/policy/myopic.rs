//! Myopic one-step policy (Ahn et al. [22], discussed in §2).
//!
//! Dispatch each arriving task to the processor that maximizes the
//! *instantaneous* post-placement throughput X(S⁺) — i.e. greedily
//! maximize Eq. 28 one arrival at a time, with no look-ahead.  The paper
//! cites this family as "optimal under certain conditions by assuming no
//! further arrivals"; in the closed system it is a strong heuristic but
//! not CAB: the ablation bench (`benches/ablation_myopic.rs`) quantifies
//! the gap in the biased regimes, where greedy placement refuses the
//! short-term sacrifice that the AF state requires.

use crate::model::throughput::x_df_plus;
use crate::sim::rng::Rng;

use super::{Policy, SystemView};

/// The myopic one-step-lookahead policy.
#[derive(Debug, Default)]
pub struct Myopic;

impl Policy for Myopic {
    fn name(&self) -> &'static str {
        "Myopic"
    }

    fn dispatch(&mut self, ttype: usize, view: &SystemView<'_>, _rng: &mut Rng) -> usize {
        // argmax_j ΔX of adding this task to processor j (Eq. 34); the
        // column deltas are exact, so this maximizes X(S⁺).
        let mut best = 0usize;
        let mut best_gain = f64::NEG_INFINITY;
        for j in 0..view.mu.procs() {
            let gain = x_df_plus(view.mu, view.state, ttype, j);
            if gain > best_gain {
                best = j;
                best_gain = gain;
            }
        }
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::affinity::AffinityMatrix;
    use crate::model::state::StateMatrix;
    use crate::model::throughput::x_of_state;

    #[test]
    fn maximizes_post_placement_throughput() {
        let mu = AffinityMatrix::two_type(20.0, 15.0, 3.0, 8.0).unwrap();
        let state = StateMatrix::new(2, 2, vec![2, 1, 1, 3]).unwrap();
        let work = vec![0.0; 2];
        let view = SystemView { mu: &mu, state: &state, work: &work, populations: &[4, 4] };
        let mut p = Myopic;
        let j = p.dispatch(0, &view, &mut Rng::new(0));
        // Verify against brute force.
        let mut best = (0usize, f64::MIN);
        for cand in 0..2 {
            let mut s2 = state.clone();
            s2.inc(0, cand);
            let x = x_of_state(&mu, &s2);
            if x > best.1 {
                best = (cand, x);
            }
        }
        assert_eq!(j, best.0);
    }

    #[test]
    fn empty_system_prefers_fastest_processor() {
        let mu = AffinityMatrix::two_type(20.0, 15.0, 3.0, 8.0).unwrap();
        let state = StateMatrix::zeros(2, 2);
        let work = vec![0.0; 2];
        let view = SystemView { mu: &mu, state: &state, work: &work, populations: &[1, 1] };
        let mut p = Myopic;
        assert_eq!(p.dispatch(0, &view, &mut Rng::new(0)), 0); // μ11 = 20
        assert_eq!(p.dispatch(1, &view, &mut Rng::new(0)), 1); // μ22 = 8
    }
}
