//! Deficit steering toward a target state S_max (Lemma 2: "always stay in
//! the state that maximizes X(S)").
//!
//! CAB, GrIn and Opt all reduce to: solve for a target matrix N* once,
//! then on every arrival send the i-type task to a processor whose i-row
//! cell is *under* target.  In the closed system the per-type populations
//! are conserved, so after the initial fill the deficit is always exactly
//! the cell the departing task vacated — the system provably stays in
//! S_max (see `tests/policy_invariants.rs` for the property test).

// srclint: allow-file(index-reachable) — target vectors are k by l from the solved allocation

use crate::model::state::StateMatrix;

use super::SystemView;

/// Argmax over (deficit, rate) pairs: largest deficit, ties to the
/// faster rate, then the lower index — the one steering tie-break rule,
/// shared by [`TargetSteering::dispatch`] and both levels of the
/// sharded plane ([`crate::coordinator::ShardLeader`] device pick,
/// [`crate::coordinator::ShardedControl`] shard pick).
///
/// Returns `None` only for an empty iterator (no devices/shards to pick
/// from).  Call sites propagate the `None` — as a routed-elsewhere
/// decision or a typed [`crate::error::Error::NoCapacity`] when every
/// candidate is down — instead of the old silent index-0 fallback (or,
/// worse, a panic while the fleet is churning).
/// The rate tie-break uses [`f64::total_cmp`], so a NaN rate orders
/// deterministically (above +∞ in IEEE total order) rather than being
/// silently unbeatable-yet-never-winning as with a `>` comparison; the
/// rate inputs at every call site are the *solved* rates of the
/// installed target, which re-solves assemble from the
/// confidence-gated μ̂
/// ([`crate::coordinator::RateEstimator::mu_hat_gated`]) — so a stale
/// cell's frozen pre-flip estimate can never win a steering tie.
pub(crate) fn pick_by_deficit(pairs: impl Iterator<Item = (i64, f64)>) -> Option<usize> {
    let mut best: Option<(usize, i64, f64)> = None;
    for (i, (deficit, rate)) in pairs.enumerate() {
        let better = match best {
            None => true,
            Some((_, bd, br)) => {
                deficit > bd || (deficit == bd && rate.total_cmp(&br).is_gt())
            }
        };
        if better {
            best = Some((i, deficit, rate));
        }
    }
    best.map(|(i, _, _)| i)
}

/// Confidence-weighted deficit score: a positive deficit (a claim on
/// the cell) is discounted by the cell weight, while overflow (a
/// negative deficit) is compared unweighted — scaling overflow by a
/// small weight would make the *least-trusted, most-overfull* cell
/// look least overfull and attract exactly the traffic it should
/// repel.
pub(crate) fn weighted_deficit(weight: f64, deficit: i64) -> f64 {
    if deficit > 0 {
        weight * deficit as f64
    } else {
        deficit as f64
    }
}

/// [`pick_by_deficit`] over priority/confidence-weighted deficits:
/// largest weighted deficit w_ij·(N*_ij − N_ij), ties (exact
/// [`f64::total_cmp`] equality) to the larger weighted rate, then the
/// lower index.  The weighted planes route through this so a deficit on
/// a low-confidence cell is discounted against one the estimator
/// actually trusts.
pub(crate) fn pick_by_weighted_deficit(
    pairs: impl Iterator<Item = (f64, f64)>,
) -> Option<usize> {
    let mut best: Option<(usize, f64, f64)> = None;
    for (i, (deficit, rate)) in pairs.enumerate() {
        let better = match best {
            None => true,
            Some((_, bd, br)) => match deficit.total_cmp(&bd) {
                std::cmp::Ordering::Greater => true,
                std::cmp::Ordering::Equal => rate.total_cmp(&br).is_gt(),
                std::cmp::Ordering::Less => false,
            },
        };
        if better {
            best = Some((i, deficit, rate));
        }
    }
    best.map(|(i, _, _)| i)
}

/// Steers arrivals toward a fixed target state.
#[derive(Debug, Clone)]
pub struct TargetSteering {
    target: StateMatrix,
    /// Per-cell steering weights (row-major k×l; empty = unweighted).
    /// Priority × estimate confidence from the weighted solve that
    /// produced `target` — the weights and the target always swap
    /// together, so steering never mixes an old weight vector with a
    /// new target.
    weights: Vec<f64>,
}

impl TargetSteering {
    /// Steer toward `target`.
    pub fn new(target: StateMatrix) -> Self {
        Self { target, weights: Vec::new() }
    }

    /// Steer toward `target` under per-cell priority weights (row-major
    /// k×l, as produced by [`crate::policy::grin::priority_weights`]).
    pub fn with_weights(target: StateMatrix, weights: Vec<f64>) -> Self {
        debug_assert_eq!(weights.len(), target.types() * target.procs());
        Self { target, weights }
    }

    /// The target matrix.
    pub fn target(&self) -> &StateMatrix {
        &self.target
    }

    /// Choose the processor for an arriving `ttype` task.
    ///
    /// Primary rule: the largest deficit `N*_ij − N_ij` (weighted by the
    /// per-cell priority/confidence weights when present).  If no cell
    /// of the row is under target (possible transiently when the
    /// population mix drifts from what the target was solved for), fall
    /// back to the fastest processor for the type among the
    /// least-overfull cells.
    ///
    /// Returns `None` only when there is no routable processor at all —
    /// impossible for a full fleet (targets always have ≥ 1 column) but
    /// reachable through [`Self::dispatch_among`] when every device is
    /// marked down.  Callers propagate the `None` as a routed-elsewhere
    /// decision or a typed [`crate::error::Error::NoCapacity`]; never a
    /// panic.
    pub fn dispatch(&self, ttype: usize, view: &SystemView<'_>) -> Option<usize> {
        self.dispatch_among(ttype, view, None)
    }

    /// [`Self::dispatch`] restricted to processors whose `alive` flag is
    /// set.  Dead columns are assigned a sentinel (`i64::MIN` deficit,
    /// `-∞` rate) so any live column dominates them without allocating a
    /// filtered candidate list on the dispatch hot path; if the winner is
    /// itself dead, the whole fleet is down and the pick is `None`.
    pub fn dispatch_among(
        &self,
        ttype: usize,
        view: &SystemView<'_>,
        alive: Option<&[bool]>,
    ) -> Option<usize> {
        let l = self.target.procs();
        debug_assert_eq!(view.state.procs(), l);
        let up = |j: usize| alive.map_or(true, |a| a[j]);
        let deficit = |j: usize| {
            self.target.get(ttype, j) as i64 - view.state.get(ttype, j) as i64
        };
        if self.weights.is_empty() {
            pick_by_deficit((0..l).map(|j| {
                if up(j) {
                    (deficit(j), view.mu.rate(ttype, j))
                } else {
                    (i64::MIN, f64::NEG_INFINITY)
                }
            }))
        } else {
            pick_by_weighted_deficit((0..l).map(|j| {
                if up(j) {
                    let w = self.weights[ttype * l + j];
                    (weighted_deficit(w, deficit(j)), w * view.mu.rate(ttype, j))
                } else {
                    (f64::NEG_INFINITY, f64::NEG_INFINITY)
                }
            }))
        }
        .filter(|&j| up(j))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::affinity::AffinityMatrix;
    use crate::sim::rng::Rng;

    fn view<'a>(
        mu: &'a AffinityMatrix,
        state: &'a StateMatrix,
        work: &'a [f64],
        populations: &'a [u32],
    ) -> SystemView<'a> {
        SystemView { mu, state, work, populations }
    }

    #[test]
    fn pick_by_deficit_empty_is_none_not_zero() {
        // Regression: the old implementation returned index 0 for an
        // empty iterator, a phantom device.
        assert_eq!(pick_by_deficit(std::iter::empty()), None);
        assert_eq!(pick_by_weighted_deficit(std::iter::empty()), None);
    }

    #[test]
    fn pick_by_deficit_nan_rate_ties_are_deterministic() {
        // Regression: with the old `rate > best_rate` tie-break a NaN
        // rate could never win a tie (NaN fails every `>`), so a
        // poisoned-rate leader silently lost every tie no matter its
        // deficit standing.  Under total_cmp the comparison is a total
        // order: +NaN sits above +∞, so the NaN entry wins its ties
        // consistently in either iteration order.
        let nan = f64::NAN;
        assert_eq!(pick_by_deficit([(3, nan), (3, 10.0)].into_iter()), Some(0));
        assert_eq!(pick_by_deficit([(3, 10.0), (3, nan)].into_iter()), Some(1));
        // A larger deficit still dominates any rate, NaN included.
        assert_eq!(pick_by_deficit([(4, 1.0), (3, nan)].into_iter()), Some(0));
        assert_eq!(pick_by_weighted_deficit([(3.0, nan), (3.0, 10.0)].into_iter()), Some(0));
        // NaN *deficits* order deterministically too (above every real).
        assert_eq!(pick_by_weighted_deficit([(1.0, 5.0), (nan, 1.0)].into_iter()), Some(1));
    }

    #[test]
    fn pick_by_deficit_all_ties_takes_lowest_index() {
        assert_eq!(pick_by_deficit([(2, 7.0), (2, 7.0), (2, 7.0)].into_iter()), Some(0));
        assert_eq!(
            pick_by_weighted_deficit([(2.0, 7.0), (2.0, 7.0), (2.0, 7.0)].into_iter()),
            Some(0)
        );
        // Equal deficits, distinct rates: the faster one wins.
        assert_eq!(pick_by_deficit([(2, 7.0), (2, 9.0)].into_iter()), Some(1));
    }

    #[test]
    fn weighted_dispatch_discounts_low_confidence_deficits() {
        let mu = AffinityMatrix::two_type(20.0, 15.0, 3.0, 8.0).unwrap();
        // Row-0 target has a deficit of 1 on both devices.
        let target = StateMatrix::new(2, 2, vec![1, 1, 0, 2]).unwrap();
        let state = StateMatrix::new(2, 2, vec![0, 0, 0, 2]).unwrap();
        let work = vec![0.0; 2];
        let view = SystemView { mu: &mu, state: &state, work: &work, populations: &[2, 2] };
        // Unweighted: equal deficits, tie to the faster device (0).
        assert_eq!(TargetSteering::new(target.clone()).dispatch(0, &view), Some(0));
        // Device 0's estimate has low confidence: its weighted deficit
        // (0.5·1) loses to device 1's (1.0·1) despite the faster rate.
        let weights = vec![0.5, 1.0, 1.0, 1.0];
        let steer = TargetSteering::with_weights(target, weights);
        assert_eq!(steer.dispatch(0, &view), Some(1));
    }

    #[test]
    fn weighted_dispatch_never_prefers_more_overfull_low_confidence_cells() {
        // Regression: scaling a *negative* deficit by a small weight
        // used to make the least-trusted, most-overfull cell look
        // least overfull — attracting exactly the traffic it should
        // repel.  Overflow comparisons stay unweighted.
        let mu = AffinityMatrix::two_type(10.0, 10.0, 10.0, 10.0).unwrap();
        let target = StateMatrix::new(2, 2, vec![0, 0, 1, 1]).unwrap();
        // Row 0 overfull everywhere: device 0 (trusted, w = 1) by 2,
        // device 1 (low confidence, w = 0.25) by 3.
        let state = StateMatrix::new(2, 2, vec![2, 3, 1, 1]).unwrap();
        let work = vec![0.0; 2];
        let v = view(&mu, &state, &work, &[6, 2]);
        let steer =
            TargetSteering::with_weights(target, vec![1.0, 0.25, 1.0, 1.0]);
        assert_eq!(steer.dispatch(0, &v), Some(0), "overflow comparison must stay unweighted");
        // The scalar rule itself: claims scale, overflow does not.
        assert_eq!(weighted_deficit(0.25, 4), 1.0);
        assert_eq!(weighted_deficit(0.25, -4), -4.0);
        assert_eq!(weighted_deficit(0.25, 0), 0.0);
    }

    #[test]
    fn fills_deficit_cells_first() {
        let mu = AffinityMatrix::two_type(20.0, 15.0, 3.0, 8.0).unwrap();
        // P1-biased target (1, N2) with N1=2, N2=18: [[1,1],[0,18]].
        let target = StateMatrix::from_two_type(1, 18, 2, 18).unwrap();
        let steer = TargetSteering::new(target);
        // Current state is the target minus the task that just left (0,0).
        let state = StateMatrix::new(2, 2, vec![0, 1, 0, 18]).unwrap();
        let work = vec![0.0; 2];
        let v = view(&mu, &state, &work, &[2, 18]);
        assert_eq!(steer.dispatch(0, &v), Some(0));
        // And minus a type-2 task from P2 instead.
        let state = StateMatrix::new(2, 2, vec![1, 1, 0, 17]).unwrap();
        let v = view(&mu, &state, &work, &[2, 18]);
        assert_eq!(steer.dispatch(1, &v), Some(1));
    }

    #[test]
    fn overfull_falls_back_to_fastest() {
        let mu = AffinityMatrix::two_type(20.0, 15.0, 3.0, 8.0).unwrap();
        let target = StateMatrix::new(2, 2, vec![1, 0, 0, 1]).unwrap();
        let steer = TargetSteering::new(target);
        // Row 0 already at/above target everywhere: equal deficits (0, -?)...
        let state = StateMatrix::new(2, 2, vec![1, 0, 0, 1]).unwrap();
        let work = vec![0.0; 2];
        let v = view(&mu, &state, &work, &[1, 1]);
        // deficit (0,0) = 0, (0,1) = 0: tie → faster rate wins (μ11=20).
        assert_eq!(steer.dispatch(0, &v), Some(0));
    }

    #[test]
    fn all_down_fleet_dispatches_none_not_panic() {
        // Regression for the churn work: dispatch used to `expect` a
        // non-empty candidate set; with every device down the pick must
        // propagate as `None`, never a panic.
        let mu = AffinityMatrix::two_type(20.0, 15.0, 3.0, 8.0).unwrap();
        let target = StateMatrix::from_two_type(1, 10, 10, 10).unwrap();
        let state = StateMatrix::new(2, 2, vec![0, 10, 0, 10]).unwrap();
        let work = vec![0.0; 2];
        let v = view(&mu, &state, &work, &[10, 10]);
        let steer = TargetSteering::new(target.clone());
        assert_eq!(steer.dispatch_among(0, &v, Some(&[false, false])), None);
        // Weighted steering propagates the same way.
        let weighted =
            TargetSteering::with_weights(target, vec![1.0, 0.5, 1.0, 1.0]);
        assert_eq!(weighted.dispatch_among(0, &v, Some(&[false, false])), None);
    }

    #[test]
    fn dispatch_among_skips_down_devices() {
        let mu = AffinityMatrix::two_type(20.0, 15.0, 3.0, 8.0).unwrap();
        // Deficit row 0: device 0 has the larger deficit AND the faster
        // rate — it would win every unfiltered pick.
        let target = StateMatrix::new(2, 2, vec![3, 1, 0, 2]).unwrap();
        let state = StateMatrix::new(2, 2, vec![0, 0, 0, 2]).unwrap();
        let work = vec![0.0; 2];
        let v = view(&mu, &state, &work, &[4, 2]);
        let steer = TargetSteering::new(target);
        assert_eq!(steer.dispatch_among(0, &v, None), Some(0));
        // Down-masking device 0 reroutes the pick to the survivor.
        assert_eq!(steer.dispatch_among(0, &v, Some(&[false, true])), Some(1));
        // An all-true mask is exactly the unfiltered pick.
        assert_eq!(steer.dispatch_among(0, &v, Some(&[true, true])), Some(0));
    }

    #[test]
    fn closed_loop_stays_at_target() {
        // Simulate the dispatch/depart cycle: state must return to target
        // after every (departure, arrival) pair, from any departure cell.
        let mu = AffinityMatrix::two_type(20.0, 15.0, 3.0, 8.0).unwrap();
        let target = StateMatrix::from_two_type(1, 10, 10, 10).unwrap();
        let steer = TargetSteering::new(target.clone());
        let mut rng = Rng::new(42);
        let mut state = target.clone();
        let work = vec![0.0; 2];
        for _ in 0..1000 {
            // Random departure from a non-empty cell.
            let (mut i, mut j);
            loop {
                i = rng.index(2);
                j = rng.index(2);
                if state.get(i, j) > 0 {
                    break;
                }
            }
            state.dec(i, j).unwrap();
            let v = SystemView { mu: &mu, state: &state, work: &work, populations: &[10, 10] };
            let dest = steer.dispatch(i, &v).expect("full fleet always routes");
            state.inc(i, dest);
            assert_eq!(state, target, "drifted from S_max");
        }
    }
}
