//! Deficit steering toward a target state S_max (Lemma 2: "always stay in
//! the state that maximizes X(S)").
//!
//! CAB, GrIn and Opt all reduce to: solve for a target matrix N* once,
//! then on every arrival send the i-type task to a processor whose i-row
//! cell is *under* target.  In the closed system the per-type populations
//! are conserved, so after the initial fill the deficit is always exactly
//! the cell the departing task vacated — the system provably stays in
//! S_max (see `tests/policy_invariants.rs` for the property test).

use crate::model::state::StateMatrix;

use super::SystemView;

/// Argmax over (deficit, rate) pairs: largest deficit, ties to the
/// faster rate, then the lower index — the one steering tie-break rule,
/// shared by [`TargetSteering::dispatch`] and both levels of the
/// sharded plane ([`crate::coordinator::ShardLeader`] device pick,
/// [`crate::coordinator::ShardedControl`] shard pick).
///
/// The rate inputs at every call site are the *solved* rates of the
/// installed target, which re-solves assemble from the
/// confidence-gated μ̂
/// ([`crate::coordinator::RateEstimator::mu_hat_gated`]) — so a stale
/// cell's frozen pre-flip estimate can never win a steering tie.
pub(crate) fn pick_by_deficit(pairs: impl Iterator<Item = (i64, f64)>) -> usize {
    let mut best = 0usize;
    let mut best_deficit = i64::MIN;
    let mut best_rate = f64::NEG_INFINITY;
    for (i, (deficit, rate)) in pairs.enumerate() {
        if deficit > best_deficit || (deficit == best_deficit && rate > best_rate) {
            best = i;
            best_deficit = deficit;
            best_rate = rate;
        }
    }
    best
}

/// Steers arrivals toward a fixed target state.
#[derive(Debug, Clone)]
pub struct TargetSteering {
    target: StateMatrix,
}

impl TargetSteering {
    /// Steer toward `target`.
    pub fn new(target: StateMatrix) -> Self {
        Self { target }
    }

    /// The target matrix.
    pub fn target(&self) -> &StateMatrix {
        &self.target
    }

    /// Choose the processor for an arriving `ttype` task.
    ///
    /// Primary rule: the largest deficit `N*_ij − N_ij`.  If no cell of the
    /// row is under target (possible transiently when the population mix
    /// drifts from what the target was solved for), fall back to the
    /// fastest processor for the type among the least-overfull cells.
    pub fn dispatch(&self, ttype: usize, view: &SystemView<'_>) -> usize {
        let l = self.target.procs();
        debug_assert_eq!(view.state.procs(), l);
        pick_by_deficit((0..l).map(|j| {
            (
                self.target.get(ttype, j) as i64 - view.state.get(ttype, j) as i64,
                view.mu.rate(ttype, j),
            )
        }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::affinity::AffinityMatrix;
    use crate::sim::rng::Rng;

    fn view<'a>(
        mu: &'a AffinityMatrix,
        state: &'a StateMatrix,
        work: &'a [f64],
        populations: &'a [u32],
    ) -> SystemView<'a> {
        SystemView { mu, state, work, populations }
    }

    #[test]
    fn fills_deficit_cells_first() {
        let mu = AffinityMatrix::two_type(20.0, 15.0, 3.0, 8.0).unwrap();
        // P1-biased target (1, N2) with N1=2, N2=18: [[1,1],[0,18]].
        let target = StateMatrix::from_two_type(1, 18, 2, 18).unwrap();
        let steer = TargetSteering::new(target);
        // Current state is the target minus the task that just left (0,0).
        let state = StateMatrix::new(2, 2, vec![0, 1, 0, 18]).unwrap();
        let work = vec![0.0; 2];
        let v = view(&mu, &state, &work, &[2, 18]);
        assert_eq!(steer.dispatch(0, &v), 0);
        // And minus a type-2 task from P2 instead.
        let state = StateMatrix::new(2, 2, vec![1, 1, 0, 17]).unwrap();
        let v = view(&mu, &state, &work, &[2, 18]);
        assert_eq!(steer.dispatch(1, &v), 1);
    }

    #[test]
    fn overfull_falls_back_to_fastest() {
        let mu = AffinityMatrix::two_type(20.0, 15.0, 3.0, 8.0).unwrap();
        let target = StateMatrix::new(2, 2, vec![1, 0, 0, 1]).unwrap();
        let steer = TargetSteering::new(target);
        // Row 0 already at/above target everywhere: equal deficits (0, -?)...
        let state = StateMatrix::new(2, 2, vec![1, 0, 0, 1]).unwrap();
        let work = vec![0.0; 2];
        let v = view(&mu, &state, &work, &[1, 1]);
        // deficit (0,0) = 0, (0,1) = 0: tie → faster rate wins (μ11=20).
        assert_eq!(steer.dispatch(0, &v), 0);
    }

    #[test]
    fn closed_loop_stays_at_target() {
        // Simulate the dispatch/depart cycle: state must return to target
        // after every (departure, arrival) pair, from any departure cell.
        let mu = AffinityMatrix::two_type(20.0, 15.0, 3.0, 8.0).unwrap();
        let target = StateMatrix::from_two_type(1, 10, 10, 10).unwrap();
        let steer = TargetSteering::new(target.clone());
        let mut rng = Rng::new(42);
        let mut state = target.clone();
        let work = vec![0.0; 2];
        for _ in 0..1000 {
            // Random departure from a non-empty cell.
            let (mut i, mut j);
            loop {
                i = rng.index(2);
                j = rng.index(2);
                if state.get(i, j) > 0 {
                    break;
                }
            }
            state.dec(i, j).unwrap();
            let v = SystemView { mu: &mu, state: &state, work: &work, populations: &[10, 10] };
            let dest = steer.dispatch(i, &v);
            state.inc(i, dest);
            assert_eq!(state, target, "drifted from S_max");
        }
    }
}
