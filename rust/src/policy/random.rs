//! RD: dispatch uniformly at random over processor types (§5 baseline 1).

use super::{Policy, SystemView};
use crate::sim::rng::Rng;

/// The Random baseline.
#[derive(Debug, Default)]
pub struct RandomPolicy;

impl Policy for RandomPolicy {
    fn name(&self) -> &'static str {
        "RD"
    }

    fn dispatch(&mut self, _ttype: usize, view: &SystemView<'_>, rng: &mut Rng) -> usize {
        rng.index(view.mu.procs())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::affinity::AffinityMatrix;
    use crate::model::state::StateMatrix;

    #[test]
    fn covers_all_processors_uniformly() {
        let mu = AffinityMatrix::from_rows(&[vec![1.0, 2.0, 3.0]]).unwrap();
        let state = StateMatrix::zeros(1, 3);
        let work = vec![0.0; 3];
        let view = SystemView { mu: &mu, state: &state, work: &work, populations: &[9] };
        let mut rng = Rng::new(1);
        let mut p = RandomPolicy;
        let mut counts = [0usize; 3];
        for _ in 0..3000 {
            counts[p.dispatch(0, &view, &mut rng)] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 1000.0).abs() < 150.0, "{counts:?}");
        }
    }
}
