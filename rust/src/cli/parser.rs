//! Typed flag parser: `--key value`, `--key=value`, boolean switches and
//! positionals, with unknown-flag detection at `finish()`.

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// Parsed arguments.
#[derive(Debug, Clone)]
pub struct Args {
    positionals: Vec<String>,
    // BTreeMap, not HashMap: flag storage stays iteration-ordered so
    // nothing downstream can pick up hash-order nondeterminism.
    flags: BTreeMap<String, Vec<String>>,
    switches: Vec<String>,
    consumed: std::cell::RefCell<Vec<String>>,
}

impl Args {
    /// Parse a raw argv tail (without the program name).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Self> {
        let mut positionals = Vec::new();
        let mut flags: BTreeMap<String, Vec<String>> = BTreeMap::new();
        let mut switches = Vec::new();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if rest.is_empty() {
                    // `--` terminator: everything after is positional.
                    positionals.extend(it.by_ref());
                    break;
                }
                if let Some((k, v)) = rest.split_once('=') {
                    flags.entry(k.to_string()).or_default().push(v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    // srclint: allow(panic-reachable) — peek() just returned Some, so next() cannot fail.
                    let v = it.next().unwrap();
                    flags.entry(rest.to_string()).or_default().push(v);
                } else {
                    switches.push(rest.to_string());
                }
            } else {
                positionals.push(a);
            }
        }
        Ok(Self {
            positionals,
            flags,
            switches,
            consumed: std::cell::RefCell::new(Vec::new()),
        })
    }

    /// Parse the process arguments.
    pub fn from_env() -> Result<Self> {
        Self::parse(std::env::args().skip(1))
    }

    /// Positional arguments.
    pub fn positionals(&self) -> &[String] {
        &self.positionals
    }

    /// First positional (the subcommand), if any.
    pub fn subcommand(&self) -> Option<&str> {
        self.positionals.first().map(|s| s.as_str())
    }

    /// Raw string flag.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.consumed.borrow_mut().push(key.to_string());
        self.flags.get(key).and_then(|v| v.last()).map(|s| s.as_str())
    }

    /// All values of a repeatable flag.
    pub fn get_all(&self, key: &str) -> Vec<&str> {
        self.consumed.borrow_mut().push(key.to_string());
        self.flags
            .get(key)
            .map(|v| v.iter().map(|s| s.as_str()).collect())
            .unwrap_or_default()
    }

    /// Typed flag with default.
    pub fn get_parse<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T> {
        match self.get(key) {
            None => Ok(default),
            Some(s) => s.parse::<T>().map_err(|_| {
                Error::Parse(format!("--{key}: cannot parse '{s}'"))
            }),
        }
    }

    /// Boolean switch (present without value).
    pub fn switch(&self, key: &str) -> bool {
        self.consumed.borrow_mut().push(key.to_string());
        self.switches.iter().any(|s| s == key) || self.flags.contains_key(key)
    }

    /// Consume the flags the cargo bench/test harness injects
    /// (`--bench`, `--exact`, `--nocapture`) so `finish()` accepts them.
    pub fn ignore_harness_flags(&self) {
        for f in ["bench", "exact", "nocapture", "test-threads"] {
            // srclint: allow(discarded-result) — switch() is called purely for its consume side effect.
            let _ = self.switch(f);
        }
    }

    /// Build a capability-gated [`Knobs`] view over these arguments.
    pub fn knobs<'a>(&'a self, table: &'static [Knob]) -> Knobs<'a> {
        Knobs { args: self, table, caps: Vec::new() }
    }

    /// Error on flags that were never consumed (typo protection),
    /// naming every offender at once so a multi-typo invocation is fixed
    /// in one round trip — and appending the flags the command *does*
    /// accept (everything it looked up before finishing), so a typo like
    /// `serve --shardz` is self-diagnosing.
    pub fn finish(&self) -> Result<()> {
        let consumed = self.consumed.borrow();
        let mut unknown: Vec<&str> = self
            .flags
            .keys()
            .chain(self.switches.iter())
            .filter(|k| !consumed.iter().any(|c| &c == k))
            .map(|k| k.as_str())
            .collect();
        if unknown.is_empty() {
            return Ok(());
        }
        unknown.sort_unstable();
        unknown.dedup();
        let list = unknown
            .iter()
            .map(|k| format!("--{k}"))
            .collect::<Vec<_>>()
            .join(", ");
        let mut known: Vec<String> = consumed.iter().map(|k| format!("--{k}")).collect();
        known.sort_unstable();
        known.dedup();
        let mut msg = format!("unknown flag(s) {list}");
        if !known.is_empty() {
            msg.push_str(&format!("; accepted flags: {}", known.join(", ")));
        }
        Err(Error::Config(msg))
    }
}

/// One declared knob: a flag that is only meaningful when a named
/// capability of the current invocation is enabled.
#[derive(Debug, Clone, Copy)]
pub struct Knob {
    /// Flag name, without the `--` prefix.
    pub flag: &'static str,
    /// Capability that must be enabled for the flag to be consumable.
    pub cap: &'static str,
}

/// Declarative, capability-gated view over [`Args`].
///
/// Commands declare each conditional knob once in a static [`Knob`]
/// table, then enable the capabilities the current invocation actually
/// supports (`--compare` runs a sharded arm, the GrIn policy consumes a
/// weighted solve, …).  Lookups on a knob whose capability is disabled
/// return the default *without consuming the flag*, so a stray use still
/// surfaces through [`Args::finish`] with the exact unknown-flag error
/// the hand-rolled per-command gating used to produce.  Flags absent
/// from the table are unconditional and pass straight through.
#[derive(Debug)]
pub struct Knobs<'a> {
    args: &'a Args,
    table: &'static [Knob],
    caps: Vec<&'static str>,
}

impl<'a> Knobs<'a> {
    /// Enable a capability (idempotent).
    pub fn enable(&mut self, cap: &'static str) {
        if !self.caps.contains(&cap) {
            self.caps.push(cap);
        }
    }

    /// Enable a capability iff `on` holds.
    pub fn enable_if(&mut self, on: bool, cap: &'static str) {
        if on {
            self.enable(cap);
        }
    }

    /// Is a capability enabled?
    pub fn enabled(&self, cap: &str) -> bool {
        self.caps.iter().any(|c| *c == cap)
    }

    /// May `key` be consumed under the enabled capabilities?
    fn open(&self, key: &str) -> bool {
        match self.table.iter().find(|k| k.flag == key) {
            None => true,
            Some(k) => self.enabled(k.cap),
        }
    }

    /// Gated [`Args::get`]: `None` (unconsumed) when the knob is closed.
    pub fn get(&self, key: &str) -> Option<&'a str> {
        if self.open(key) {
            self.args.get(key)
        } else {
            None
        }
    }

    /// Gated [`Args::get_parse`]: the default when the knob is closed.
    pub fn get_parse<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T> {
        if self.open(key) {
            self.args.get_parse(key, default)
        } else {
            Ok(default)
        }
    }

    /// Gated [`Args::switch`]: `false` when the knob is closed.
    pub fn switch(&self, key: &str) -> bool {
        if self.open(key) {
            self.args.switch(key)
        } else {
            false
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn parses_subcommand_flags_switches() {
        // Note: a bare switch must not be directly followed by a
        // positional (`--verbose pos2` would read pos2 as its value) —
        // the standard greedy-value convention.
        let a = args("simulate pos2 --policy cab --eta=0.3 --verbose");
        assert_eq!(a.subcommand(), Some("simulate"));
        assert_eq!(a.get("policy"), Some("cab"));
        assert_eq!(a.get_parse("eta", 0.0).unwrap(), 0.3);
        assert!(a.switch("verbose"));
        assert_eq!(a.positionals(), &["simulate", "pos2"]);
        a.finish().unwrap();
    }

    #[test]
    fn typed_defaults_and_errors() {
        let a = args("run --n 20");
        assert_eq!(a.get_parse("n", 5u32).unwrap(), 20);
        assert_eq!(a.get_parse("seed", 7u64).unwrap(), 7);
        let a = args("run --n abc");
        assert!(a.get_parse("n", 5u32).is_err());
    }

    #[test]
    fn unknown_flags_are_rejected() {
        let a = args("run --good 1 --oops 2");
        let _ = a.get("good");
        assert!(a.finish().is_err());
        let _ = a.get("oops");
        a.finish().unwrap();
    }

    #[test]
    fn finish_names_every_unknown_flag() {
        let a = args("run --good 1 --typo 2 --worse");
        let _ = a.get("good");
        let msg = a.finish().unwrap_err().to_string();
        assert!(msg.contains("--typo") && msg.contains("--worse"), "{msg}");
    }

    #[test]
    fn finish_lists_the_accepted_flag_set() {
        // A typo'd flag name is self-diagnosing: the error carries the
        // flags the command actually looked up.
        let a = args("serve --shardz 3");
        let _ = a.get("policy");
        let _ = a.get_parse("shards", 1usize);
        let msg = a.finish().unwrap_err().to_string();
        assert!(msg.contains("unknown flag(s) --shardz"), "{msg}");
        assert!(msg.contains("accepted flags: --policy, --shards"), "{msg}");
        // With nothing consumed there is no accepted set to offer.
        let a = args("run --oops 1");
        let msg = a.finish().unwrap_err().to_string();
        assert!(!msg.contains("accepted"), "{msg}");
    }

    #[test]
    fn knobs_gate_consumption_by_capability() {
        static TABLE: &[Knob] = &[
            Knob { flag: "trigger", cap: "estimating" },
            Knob { flag: "shards", cap: "sharded" },
        ];
        // Closed knob: the lookup returns the default and leaves the
        // flag unconsumed, so finish() flags it with the exact error.
        let a = args("scenario --trigger cusum --n 9");
        let k = a.knobs(TABLE);
        assert_eq!(k.get("trigger"), None);
        assert_eq!(k.get_parse("n", 0u32).unwrap(), 9); // undeclared = open
        let msg = a.finish().unwrap_err().to_string();
        assert!(msg.contains("unknown flag(s) --trigger"), "{msg}");
        // Open knob: consumed as usual.
        let a = args("scenario --trigger cusum --shards 2");
        let mut k = a.knobs(TABLE);
        k.enable("estimating");
        k.enable_if(true, "sharded");
        k.enable("estimating"); // idempotent
        assert!(k.enabled("estimating") && k.enabled("sharded"));
        assert_eq!(k.get("trigger"), Some("cusum"));
        assert_eq!(k.get_parse("shards", 1usize).unwrap(), 2);
        a.finish().unwrap();
        // Closed switches read as absent.
        let a = args("scenario --compare");
        let k = a.knobs(&[Knob { flag: "compare", cap: "never" }]);
        assert!(!k.switch("compare"));
        assert!(a.finish().is_err());
    }

    #[test]
    fn repeatable_and_double_dash() {
        let a = args("x --mu 1 --mu 2 -- --not-a-flag");
        assert_eq!(a.get_all("mu"), vec!["1", "2"]);
        assert_eq!(a.positionals(), &["x", "--not-a-flag"]);
        a.finish().unwrap();
    }
}
