//! The `hetsched` launcher subcommands.
//!
//! ```text
//! hetsched simulate  --config spec.json | --policy cab --eta 0.5 ...
//! hetsched sweep     --dist exp --n 20 [--policies cab,bf,rd,jsq,lb]
//!                    [--reps 16 --threads 0 --quick --json out.json]
//! hetsched solve     --mu "20,15;3,8" --populations 10,10 [--solver grin]
//! hetsched scenario  --kind slow_drift --policy grin [--compare --reps 4]
//!                    [--resolve sharded --shards N --sync-every M]
//!                    [--trigger cusum --cusum-h 4.0 --cusum-delta 0.25]
//!                    [--priorities 4,1 --deadlines 1.0,0 --threads T]
//! hetsched platform  --case p2_biased --eta 0.5 --policy cab
//! hetsched serve     --policy cab --inflight 16 --total 400 [--adaptive]
//!                    [--devices L --shards N --sync-every M]
//!                    [--trigger cusum --cusum-h 4.0 --cusum-delta 0.25]
//!                    [--priorities 4,1 --deadlines 0.05,0.1]
//! hetsched classify  --mu "20,15;3,8"
//! ```

use crate::config::schema::{ExperimentSpec, ScenarioSpec};
use crate::coordinator::{Coordinator, ServeConfig};
use crate::error::{Error, Result};
use crate::model::affinity::AffinityMatrix;
use crate::model::throughput::{x_max_theoretical, x_of_state};
use crate::platform::bench_rig::{cases, run_platform, PlatformConfig};
use crate::platform::measure_rates;
use crate::policy::PolicyKind;
use crate::report::Table;
use crate::sim::distribution::Distribution;
use crate::sim::engine::{ClosedNetwork, SimConfig};
use crate::sim::workload;
use crate::solver::exhaustive::ExhaustiveSolver;
use crate::solver::slsqp::Slsqp;

use super::parser::Args;

/// Top-level usage text.
pub const USAGE: &str = "\
hetsched — task scheduling for heterogeneous multicore systems (CAB + GrIn)

USAGE: hetsched <COMMAND> [FLAGS]

COMMANDS:
  simulate   run one closed-network simulation (JSON spec or flags)
  sweep      η-sweep of all policies (the Figs. 4–7 experiment) with R
             seeded replications per cell fanned across cores; reports
             mean X ± 95% CI (--reps, --threads, --quick, --json FILE
             writes a bit-exact snapshot for the CI determinism gate)
  solve      solve Eq. 28 for a μ matrix (grin | opt | slsqp | cab)
  scenario   run a non-stationary scenario (phase_shift | burst |
             slow_drift | abrupt_flip | priority_mix) under a resolve
             mode (static | every_phase | adaptive | sharded), or
             --compare all modes side by side plus CUSUM-triggered and
             priority-weighted adaptive arms
             (--reps/--threads replicate each arm; --shards/--sync-every
             tune the sharded control plane; --trigger threshold|cusum
             with --cusum-h/--cusum-delta picks the change detector,
             --stale-after tunes stale-cell demotion; --priorities a,b
             weights the GrIn solve per class, --deadlines x,y adds
             soft-deadline miss accounting, 0 = none)
  classify   classify a 2×2 μ matrix into its Table-1 regime
  platform   run the §7 platform emulation (needs `make artifacts`)
  serve      run the serving coordinator demo (--adaptive for live
             re-solve against estimated rates, --trigger cusum for
             change-point-triggered re-solves; --devices L --shards N
             for the sharded multi-leader plane; --priorities a,b for
             priority-weighted GrIn serving, --deadlines x,y for
             per-class latency-deadline miss rates)
  help       show this text

Run `hetsched <COMMAND> --help` for per-command flags.";

/// Parse "a,b;c,d" into an affinity matrix.
pub fn parse_mu(text: &str) -> Result<AffinityMatrix> {
    let rows: Vec<Vec<f64>> = text
        .split(';')
        .map(|row| {
            row.split(',')
                .map(|c| {
                    c.trim()
                        .parse::<f64>()
                        .map_err(|_| Error::Parse(format!("bad μ entry '{c}'")))
                })
                .collect()
        })
        .collect::<Result<_>>()?;
    AffinityMatrix::from_rows(&rows)
}

/// Parse "10,10" into populations.
pub fn parse_populations(text: &str) -> Result<Vec<u32>> {
    text.split(',')
        .map(|c| {
            c.trim()
                .parse::<u32>()
                .map_err(|_| Error::Parse(format!("bad population '{c}'")))
        })
        .collect()
}

/// Parse "4,1" into per-class integer priorities.
pub fn parse_priorities(text: &str) -> Result<Vec<u32>> {
    text.split(',')
        .map(|c| {
            c.trim()
                .parse::<u32>()
                .map_err(|_| Error::Parse(format!("bad priority '{c}'")))
        })
        .collect()
}

/// Parse "1.0,0" into per-class soft deadlines (seconds; 0 = none).
pub fn parse_deadlines(text: &str) -> Result<Vec<f64>> {
    text.split(',')
        .map(|c| {
            c.trim()
                .parse::<f64>()
                .map_err(|_| Error::Parse(format!("bad deadline '{c}'")))
        })
        .collect()
}

/// Entry point called by `main`.
pub fn run(args: &Args) -> Result<()> {
    match args.subcommand() {
        None | Some("help") => {
            println!("{USAGE}");
            Ok(())
        }
        Some("simulate") => cmd_simulate(args),
        Some("sweep") => cmd_sweep(args),
        Some("solve") => cmd_solve(args),
        Some("scenario") => cmd_scenario(args),
        Some("classify") => cmd_classify(args),
        Some("platform") => cmd_platform(args),
        Some("serve") => cmd_serve(args),
        Some(other) => Err(Error::Config(format!(
            "unknown command '{other}' — try `hetsched help`"
        ))),
    }
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let spec = if let Some(path) = args.get("config") {
        ExperimentSpec::from_file(path)?
    } else {
        let mu = parse_mu(args.get("mu").unwrap_or("20,15;3,8"))?;
        let pops = parse_populations(args.get("populations").unwrap_or("10,10"))?;
        let policy = PolicyKind::parse(args.get("policy").unwrap_or("cab"))?;
        let mut sim = SimConfig::paper_default(pops);
        sim.dist = Distribution::parse(args.get("dist").unwrap_or("exp"))?;
        sim.seed = args.get_parse("seed", sim.seed)?;
        sim.warmup = args.get_parse("warmup", sim.warmup)?;
        sim.measure = args.get_parse("measure", sim.measure)?;
        ExperimentSpec { mu, policy, sim }
    };
    args.finish()?;

    let net = ClosedNetwork::new(&spec.mu, spec.sim.clone())?;
    let mut policy = spec.policy.build();
    let r = net.run(policy.as_mut())?;
    let mut t = Table::new(
        format!("simulate: {} on {:?}", spec.policy.name(), spec.sim.dist.name()),
        &["metric", "value"],
    );
    t.row(vec!["X (tasks/s)".into(), format!("{:.4}", r.throughput)]);
    t.row(vec!["E[T] (s)".into(), format!("{:.4}", r.mean_response)]);
    t.row(vec!["E[ℰ]".into(), format!("{:.4}", r.mean_energy)]);
    t.row(vec!["EDP".into(), format!("{:.4}", r.edp)]);
    t.row(vec!["X·E[T] (≈N)".into(), format!("{:.4}", r.little_product)]);
    t.row(vec!["completions".into(), r.completed.to_string()]);
    t.print();
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<()> {
    use crate::sim::replicate::{run_cells, ReplicationPlan, SimCell};

    let mu = parse_mu(args.get("mu").unwrap_or("20,15;3,8"))?;
    let n: u32 = args.get_parse("n", 20u32)?;
    let dist = Distribution::parse(args.get("dist").unwrap_or("exp"))?;
    let seed: u64 = args.get_parse("seed", 7u64)?;
    let quick = args.switch("quick");
    let default_measure: u64 = if quick { 2_000 } else { 20_000 };
    let measure: u64 = args.get_parse("measure", default_measure)?;
    let warmup: u64 = args.get_parse("warmup", if quick { 200 } else { 2_000 })?;
    let reps: u32 = args.get_parse("reps", if quick { 4 } else { 16 })?;
    let threads: usize = args.get_parse("threads", 0usize)?;
    let json_path = args.get("json").map(str::to_string);
    let kinds: Vec<PolicyKind> = match args.get("policies") {
        Some(list) => list
            .split(',')
            .map(PolicyKind::parse)
            .collect::<Result<_>>()?,
        None => PolicyKind::five_two_type().to_vec(),
    };
    args.finish()?;

    let etas: Vec<f64> = if quick {
        vec![0.2, 0.5, 0.8]
    } else {
        workload::eta_grid().to_vec()
    };
    let mut cells = Vec::with_capacity(etas.len() * kinds.len());
    for &eta in &etas {
        let (n1, n2) = workload::split_populations(n, eta);
        for kind in &kinds {
            let mut sim = SimConfig::paper_default(vec![n1, n2]);
            sim.dist = dist;
            sim.seed = seed;
            sim.warmup = warmup;
            sim.measure = measure;
            cells.push(SimCell {
                label: format!("eta={eta:.1} {}", kind.name()),
                mu: mu.clone(),
                sim,
                policy: *kind,
            });
        }
    }
    let plan = ReplicationPlan { reps, threads, base_seed: seed };
    let t0 = std::time::Instant::now();
    let stats = run_cells(&cells, &plan)?;
    let wall = t0.elapsed().as_secs_f64();

    let mut headers: Vec<&str> = vec!["eta"];
    let names: Vec<String> = kinds.iter().map(|k| k.name().to_string()).collect();
    headers.extend(names.iter().map(|s| s.as_str()));
    let mut t = Table::new(
        format!("throughput sweep, dist={}, N={n}, R={reps} (mean ± 95% CI)", dist.name()),
        &headers,
    );
    for (ei, eta) in etas.iter().enumerate() {
        let mut row = vec![format!("{eta:.1}")];
        for ki in 0..kinds.len() {
            let s = &stats[ei * kinds.len() + ki];
            row.push(format!("{:.3} ± {:.3}", s.mean_x, s.ci95_x));
        }
        t.row(row);
    }
    t.print();
    let runs = cells.len() as u64 * reps as u64;
    println!(
        "{} cells × {} reps = {} replications on {} threads in {:.2}s ({:.1} runs/s)",
        cells.len(),
        reps,
        runs,
        plan.effective_threads(),
        wall,
        runs as f64 / wall.max(1e-9)
    );
    if let Some(path) = json_path {
        // Bit-exact per-cell snapshot for the CI determinism gate: the
        // file must be byte-identical across thread counts (seeds derive
        // from (base, cell, rep) alone and slots fix the fp sum order),
        // so the recorded thread count is deliberately omitted.
        use crate::config::json::Json;
        let cell_docs: Vec<Json> = stats
            .iter()
            .map(|s| {
                Json::Obj(vec![
                    ("label".to_string(), Json::Str(s.label.clone())),
                    ("mean_x".to_string(), Json::Num(s.mean_x)),
                    ("mean_x_bits".to_string(), Json::Str(format!("{:016x}", s.mean_x.to_bits()))),
                    ("ci95_x".to_string(), Json::Num(s.ci95_x)),
                    ("ci95_x_bits".to_string(), Json::Str(format!("{:016x}", s.ci95_x.to_bits()))),
                ])
            })
            .collect();
        let doc = Json::Obj(vec![
            (
                "sweep".to_string(),
                Json::Obj(vec![
                    ("n".to_string(), Json::Num(f64::from(n))),
                    ("reps".to_string(), Json::Num(f64::from(reps))),
                    // u64 seeds can exceed f64's exact-integer range.
                    ("seed".to_string(), Json::Str(seed.to_string())),
                    ("dist".to_string(), Json::Str(dist.name().to_string())),
                ]),
            ),
            ("cells".to_string(), Json::Arr(cell_docs)),
        ]);
        std::fs::write(&path, doc.to_string_compact())?;
        println!("wrote {path}");
    }
    Ok(())
}

fn cmd_solve(args: &Args) -> Result<()> {
    let mu = parse_mu(
        args.get("mu")
            .ok_or_else(|| Error::Config("--mu is required".into()))?,
    )?;
    let pops = parse_populations(
        args.get("populations")
            .ok_or_else(|| Error::Config("--populations is required".into()))?,
    )?;
    let solver = args.get("solver").unwrap_or("grin").to_string();
    args.finish()?;

    match solver.as_str() {
        "grin" => {
            let sol = crate::policy::grin::solve(&mu, &pops)?;
            println!("GrIn: X = {:.6} after {} moves", sol.throughput, sol.moves);
            print!("{}", sol.state);
        }
        "opt" => {
            let sol = ExhaustiveSolver.solve(&mu, &pops)?;
            println!("Opt: X = {:.6} over {} states", sol.throughput, sol.evaluated);
            print!("{}", sol.state);
        }
        "slsqp" => {
            let sol = Slsqp::default().solve(&mu, &pops)?;
            println!(
                "SLSQP: X = {:.6} in {} iterations (converged: {})",
                sol.throughput, sol.iterations, sol.converged
            );
        }
        "cab" => {
            let (regime, target) = crate::policy::cab::Cab::target_state(&mu, &pops)?;
            println!(
                "CAB: regime {} → X = {:.6}",
                regime.name(),
                x_of_state(&mu, &target)
            );
            print!("{target}");
        }
        other => {
            return Err(Error::Config(format!(
                "unknown solver '{other}' (grin|opt|slsqp|cab)"
            )))
        }
    }
    Ok(())
}

fn cmd_scenario(args: &Args) -> Result<()> {
    use crate::sim::dynamic::{run_dynamic_report, DynamicConfig, ResolveMode, Trigger};
    use crate::sim::workload::{scenario_phases, ScenarioKind, ScenarioParams};

    let (mu, policy, kind, dynamic) = if let Some(path) = args.get("config") {
        let spec = ScenarioSpec::from_file(path)?;
        (spec.mu, spec.policy, spec.kind, spec.dynamic)
    } else {
        let mu = parse_mu(args.get("mu").unwrap_or("20,15;3,8"))?;
        let policy = PolicyKind::parse(args.get("policy").unwrap_or("grin"))?;
        let kind = ScenarioKind::parse(args.get("kind").unwrap_or("slow_drift"))?;
        let d = ScenarioParams::default();
        let drift_to = match args.get("drift-to") {
            Some(list) => list
                .split(',')
                .map(|c| {
                    c.trim().parse::<f64>().map_err(|_| {
                        Error::Parse(format!("--drift-to: bad factor '{c}'"))
                    })
                })
                .collect::<Result<_>>()?,
            None => d.drift_to,
        };
        let p = ScenarioParams {
            n: args.get_parse("n", d.n)?,
            phases: args.get_parse("phases", d.phases)?,
            completions: args.get_parse("completions", d.completions)?,
            warmup: args.get_parse("warmup", d.warmup)?,
            low_eta: args.get_parse("low-eta", d.low_eta)?,
            high_eta: args.get_parse("high-eta", d.high_eta)?,
            burst_factor: args.get_parse("burst-factor", d.burst_factor)?,
            drift_to,
        };
        let mut dynamic = DynamicConfig::new(scenario_phases(kind, &p)?);
        dynamic.resolve = ResolveMode::parse(args.get("resolve").unwrap_or("adaptive"))?;
        dynamic.dist = Distribution::parse(args.get("dist").unwrap_or("exp"))?;
        dynamic.seed = args.get_parse("seed", dynamic.seed)?;
        dynamic.drift.threshold = args.get_parse("drift-threshold", dynamic.drift.threshold)?;
        dynamic.drift.check_every = args.get_parse("check-every", dynamic.drift.check_every)?;
        // The trigger and staleness knobs only drive the estimating
        // resolve modes (adaptive/sharded, or any --compare, which runs
        // both); on static/every_phase they are left unconsumed so
        // `finish()` flags them instead of silently ignoring them.
        let estimating = matches!(
            dynamic.resolve,
            ResolveMode::Adaptive | ResolveMode::Sharded
        ) || args.switch("compare");
        if estimating {
            dynamic.drift.trigger =
                Trigger::parse(args.get("trigger").unwrap_or("threshold"))?;
            dynamic.drift.stale_after =
                args.get_parse("stale-after", dynamic.drift.stale_after)?;
        }
        // Same rule, one level down, for the CUSUM knobs: they need a
        // CUSUM arm (--trigger cusum, or the --compare cusum arm).
        if dynamic.drift.trigger == Trigger::Cusum || args.switch("compare") {
            dynamic.drift.cusum_h = args.get_parse("cusum-h", dynamic.drift.cusum_h)?;
            dynamic.drift.cusum_delta = args.get_parse("cusum-delta", dynamic.drift.cusum_delta)?;
        }
        // Sharded knobs only apply when a sharded arm runs (--resolve
        // sharded or --compare); otherwise leave them unconsumed so
        // `finish()` flags them instead of silently ignoring them.
        if dynamic.resolve == ResolveMode::Sharded || args.switch("compare") {
            dynamic.shard.shards = args.get_parse("shards", dynamic.shard.shards)?;
            dynamic.shard.sync_every =
                args.get_parse("sync-every", dynamic.shard.sync_every)?;
        }
        // --priorities needs a consumer of the weighted GrIn solve —
        // the GrIn policy (directly, or via the --compare priority arm,
        // which only exists under GrIn), or a non-compare sharded run
        // (the sharded plane always steers by batched GrIn; under
        // --compare the sharded arm is deliberately unweighted).
        // Anywhere else the flag stays unconsumed so `finish()` flags
        // it instead of silently ignoring it.  The priority_mix
        // scenario defaults to the 4:1 split its canned schedule is
        // designed around.
        let weighted_capable = policy == PolicyKind::GrIn
            || (dynamic.resolve == ResolveMode::Sharded && !args.switch("compare"));
        if weighted_capable {
            let default_pri = if kind == ScenarioKind::PriorityMix { "4,1" } else { "" };
            let text = args.get("priorities").unwrap_or(default_pri);
            if !text.is_empty() {
                dynamic.priorities = parse_priorities(text)?;
            }
        }
        // Deadlines are pure accounting and apply under every resolve
        // mode/policy.
        if let Some(text) = args.get("deadlines") {
            dynamic.deadlines = parse_deadlines(text)?;
        }
        (mu, policy, kind, dynamic)
    };
    let compare = args.switch("compare");
    // Only meaningful with --compare: leaving them unconsumed otherwise
    // lets `finish()` flag stray `--reps`/`--threads` instead of
    // ignoring them.
    let reps: u32 = if compare { args.get_parse("reps", 4u32)? } else { 4 };
    let threads: usize = if compare { args.get_parse("threads", 0usize)? } else { 0 };
    args.finish()?;

    // The class whose throughput/miss lines are reported: the
    // highest-priority one (first on ties), class 0 when no priorities
    // are configured.
    let hi_class = |pri: &[u32]| -> usize {
        let top = pri.iter().copied().max().unwrap_or(0);
        pri.iter().position(|&p| p == top).unwrap_or(0)
    };
    // (per-phase X, mean X, re-solves, per-class X, per-class miss rate)
    type ArmResult = (Vec<f64>, f64, u64, Vec<f64>, Vec<f64>);
    let run_arm =
        |mode: ResolveMode, trigger: Trigger, priorities: Vec<u32>| -> Result<ArmResult> {
            let mut cfg = dynamic.clone();
            cfg.resolve = mode;
            cfg.drift.trigger = trigger;
            cfg.priorities = priorities;
            let mut p = policy.build();
            let report = run_dynamic_report(&mu, &cfg, p.as_mut())?;
            let per_phase: Vec<f64> = report.phases.iter().map(|r| r.throughput).collect();
            let k = mu.types();
            Ok((
                per_phase,
                report.mean_throughput(),
                report.resolves,
                (0..k).map(|i| report.class_throughput(i)).collect(),
                (0..k).map(|i| report.deadline_miss_rate(i)).collect(),
            ))
        };

    if compare {
        // Six arms: the four resolve modes (adaptive under the polled
        // threshold trigger), the CUSUM-triggered adaptive arm, and the
        // priority-weighted adaptive arm (configured --priorities, or
        // 4:1 by default); the sharded arm follows the configured
        // --trigger.  Independent runs, fanned across cores through the
        // replication runner's worker pool.
        let arm_pri = if dynamic.priorities.is_empty() {
            vec![4, 1]
        } else {
            dynamic.priorities.clone()
        };
        let mut arms: Vec<(ResolveMode, Trigger, bool, &str)> = vec![
            (ResolveMode::Static, Trigger::Threshold, false, "static"),
            (ResolveMode::EveryPhase, Trigger::Threshold, false, "every_phase"),
            (ResolveMode::Adaptive, Trigger::Threshold, false, "adaptive"),
            (ResolveMode::Adaptive, Trigger::Cusum, false, "cusum"),
            (ResolveMode::Sharded, dynamic.drift.trigger, false, "sharded"),
        ];
        // The weighted solve is a GrIn extension: under any other
        // --policy the comparison stays at the five unweighted arms.
        if policy == PolicyKind::GrIn {
            arms.push((ResolveMode::Adaptive, Trigger::Threshold, true, "priority"));
        }
        let results =
            crate::sim::replicate::parallel_map(&arms, 0, |_, &(mode, trig, weighted, _)| {
                run_arm(mode, trig, if weighted { arm_pri.clone() } else { Vec::new() })
            })
            .into_iter()
            .collect::<Result<Vec<_>>>()?;
        let mut headers: Vec<&str> = vec!["phase"];
        headers.extend(arms.iter().map(|&(_, _, _, label)| label));
        let mut t = Table::new(
            format!("scenario {} ({}): per-phase X by resolve mode", kind.name(), policy.name()),
            &headers,
        );
        for i in 0..dynamic.phases.len() {
            let mut row = vec![format!("{i}")];
            row.extend(results.iter().map(|r| format!("{:.4}", r.0[i])));
            t.row(row);
        }
        let mut mean_row = vec!["mean".to_string()];
        mean_row.extend(results.iter().map(|r| format!("{:.4}", r.1)));
        t.row(mean_row);
        t.print();
        let resolve_list: Vec<String> = arms
            .iter()
            .zip(&results)
            .map(|(&(_, _, _, label), r)| format!("{label} {}", r.2))
            .collect();
        println!("re-solves: {}", resolve_list.join(" / "));
        let mut summary = format!(
            "vs static mean X: adaptive {:.2}x, cusum {:.2}x, sharded {:.2}x",
            results[2].1 / results[0].1,
            results[3].1 / results[0].1,
            results[4].1 / results[0].1,
        );
        if let Some(pri) = results.get(5) {
            summary.push_str(&format!(", priority {:.2}x", pri.1 / results[0].1));
        }
        summary.push_str(&format!(
            " (oracle every_phase: {:.2}x)",
            results[1].1 / results[0].1
        ));
        println!("{summary}");
        if let Some(pri) = results.get(5) {
            let h = hi_class(&arm_pri);
            let mut hi = format!(
                "high-priority class (class {h}) X: priority {:.4} vs adaptive {:.4} \
                 ({:.2}x at {:?})",
                pri.3[h],
                results[2].3[h],
                pri.3[h] / results[2].3[h].max(1e-12),
                arm_pri,
            );
            if !dynamic.deadlines.is_empty() {
                hi.push_str(&format!(
                    "; its deadline miss: priority {:.1}% vs adaptive {:.1}%",
                    pri.4[h] * 100.0,
                    results[2].4[h] * 100.0
                ));
            }
            println!("{hi}");
        }
        if reps > 1 {
            // Replicated A/B: R seeded replications per arm through the
            // replication runner (thread-count-independent aggregates).
            use crate::sim::replicate::{run_dynamic_cells, DynCell, ReplicationPlan};
            let cells: Vec<DynCell> = arms
                .iter()
                .map(|&(mode, trig, weighted, label)| {
                    let mut cfg = dynamic.clone();
                    cfg.resolve = mode;
                    cfg.drift.trigger = trig;
                    cfg.priorities =
                        if weighted { arm_pri.clone() } else { Vec::new() };
                    DynCell {
                        label: label.to_string(),
                        mu: mu.clone(),
                        cfg,
                        policy,
                    }
                })
                .collect();
            let plan = ReplicationPlan { reps, threads, base_seed: dynamic.seed };
            let stats = run_dynamic_cells(&cells, &plan)?;
            let h = hi_class(&arm_pri);
            let with_miss = !dynamic.deadlines.is_empty();
            let x_col = format!("X(class {h})");
            let miss_col = format!("miss(class {h})");
            let mut headers = vec!["mode", "mean X", x_col.as_str()];
            if with_miss {
                headers.push(miss_col.as_str());
            }
            headers.push("re-solves/run");
            let mut t = Table::new(
                format!("replicated comparison (R = {reps}, mean ± t-corrected 95% CI)"),
                &headers,
            );
            for s in &stats {
                let mut row = vec![
                    s.label.clone(),
                    format!("{:.4} ± {:.4}", s.mean_x, s.ci95_x),
                    format!("{:.4}", s.mean_class_x[h]),
                ];
                if with_miss {
                    row.push(format!("{:.1}%", s.mean_miss_rate[h] * 100.0));
                }
                row.push(format!("{:.1}", s.mean_resolves));
                t.row(row);
            }
            t.print();
        }
    } else {
        let (per_phase, mean, resolves, class_x, class_miss) =
            run_arm(dynamic.resolve, dynamic.drift.trigger, dynamic.priorities.clone())?;
        let mut t = Table::new(
            format!(
                "scenario {} ({}, resolve {}, trigger {})",
                kind.name(),
                policy.name(),
                dynamic.resolve.name(),
                dynamic.drift.trigger.name()
            ),
            &["phase", "populations", "X (tasks/s)"],
        );
        for (i, x) in per_phase.iter().enumerate() {
            t.row(vec![
                format!("{i}"),
                format!("{:?}", dynamic.phases[i].populations),
                format!("{x:.4}"),
            ]);
        }
        t.print();
        println!("mean X = {mean:.4} tasks/s, {resolves} re-solves");
        if !dynamic.priorities.is_empty() || !dynamic.deadlines.is_empty() {
            let h = hi_class(&dynamic.priorities);
            let mut line = format!("class-{h} X = {:.4} tasks/s", class_x[h]);
            if !dynamic.priorities.is_empty() {
                line.push_str(&format!(" (priorities {:?})", dynamic.priorities));
            }
            if !dynamic.deadlines.is_empty() {
                line.push_str(&format!(", deadline miss {:.1}%", class_miss[h] * 100.0));
            }
            println!("{line}");
        }
    }
    Ok(())
}

fn cmd_classify(args: &Args) -> Result<()> {
    let mu = parse_mu(
        args.get("mu")
            .ok_or_else(|| Error::Config("--mu is required".into()))?,
    )?;
    args.finish()?;
    let regime = mu.classify()?;
    println!("regime: {}", regime.name());
    println!(
        "CAB chooses: {}",
        if regime.is_biased() { "AF (accelerate-the-fastest)" } else { "BF (best-fit)" }
    );
    let (s11, s22) = crate::model::throughput::s_max(regime, 10, 10);
    println!("S_max at N1=N2=10: ({s11}, {s22})");
    println!(
        "X_max at N1=N2=10: {:.4}",
        x_max_theoretical(&mu, regime, 10, 10)
    );
    Ok(())
}

fn cmd_platform(args: &Args) -> Result<()> {
    let case = args.get("case").unwrap_or("general_symmetric").to_string();
    let eta: f64 = args.get_parse("eta", 0.5)?;
    let n: u32 = args.get_parse("n", 20u32)?;
    let policy = PolicyKind::parse(args.get("policy").unwrap_or("cab"))?;
    let cap: u32 = args.get_parse("rep-cap", 96u32)?;
    let measure: u64 = args.get_parse("measure", 60u64)?;
    let measure_runs: u32 = args.get_parse("measure-runs", 5u32)?;
    args.finish()?;

    eprintln!("calibrating kernel baselines...");
    let cal = crate::platform::calibrate(measure_runs)?;
    let devices = match case.as_str() {
        "general_symmetric" => cases::general_symmetric(&cal, cap),
        "p2_biased" => cases::p2_biased(&cal, cap),
        other => {
            return Err(Error::Config(format!(
                "unknown case '{other}' (general_symmetric|p2_biased)"
            )))
        }
    };
    eprintln!("measuring processing rates (Table 3 analog)...");
    let rates = measure_rates(&devices, measure_runs)?;
    let mut t = Table::new("measured rates (tasks/s)", &["task", "CPU", "GPU"]);
    for (i, name) in ["sort", "nn"].iter().enumerate() {
        t.row(vec![
            name.to_string(),
            format!("{:.2}", rates.mu.rate(i, 0)),
            format!("{:.2}", rates.mu.rate(i, 1)),
        ]);
    }
    t.print();
    println!("regime: {}", rates.mu.classify()?.name());

    let (n1, n2) = workload::split_populations(n, eta);
    let cfg = PlatformConfig {
        devices,
        populations: vec![n1, n2],
        warmup: n as u64,
        measure,
        seed: 11,
    };
    let mut p = policy.build();
    let r = run_platform(&cfg, &rates, p.as_mut())?;
    println!(
        "{}: X = {:.2} tasks/s, E[T] = {:.1} ms over {} tasks (η = {eta})",
        policy.name(),
        r.throughput,
        r.mean_response_s * 1e3,
        r.completions
    );
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let d = ServeConfig::default();
    let shards: usize = args.get_parse("shards", d.shards)?;
    let policy = match args.get("policy") {
        Some(name) => PolicyKind::parse(name)?,
        // Sharded serving always steers by batched GrIn (an explicit
        // conflicting --policy is rejected by Coordinator::run).
        None if shards > 1 => PolicyKind::GrIn,
        None => PolicyKind::Cab,
    };
    if shards > 1 && args.get("resolve-check").is_some() {
        return Err(Error::Config(
            "sharded serving syncs every --sync-every completions; \
             --resolve-check is the single-leader knob"
                .into(),
        ));
    }
    let adaptive = args.switch("adaptive");
    // The trigger and staleness knobs only drive the adaptive/sharded
    // estimation loops; leaving the flags unconsumed otherwise lets
    // `finish()` flag them instead of silently ignoring them.
    let (trigger, stale_after) = if adaptive || shards > 1 {
        (
            crate::sim::dynamic::Trigger::parse(args.get("trigger").unwrap_or("threshold"))?,
            args.get_parse("stale-after", d.stale_after)?,
        )
    } else {
        (d.trigger, d.stale_after)
    };
    let (cusum_delta, cusum_h) = if trigger == crate::sim::dynamic::Trigger::Cusum {
        (
            args.get_parse("cusum-delta", d.cusum_delta)?,
            args.get_parse("cusum-h", d.cusum_h)?,
        )
    } else {
        (d.cusum_delta, d.cusum_h)
    };
    // --priorities needs the weighted GrIn solve (GrIn policy or the
    // sharded plane, which always steers by batched GrIn); elsewhere it
    // stays unconsumed so `finish()` flags it instead of silently
    // serving unweighted.  --deadlines is pure latency accounting and
    // applies to every mode.
    let priorities = if policy == PolicyKind::GrIn || shards > 1 {
        match args.get("priorities") {
            Some(text) => parse_priorities(text)?,
            None => Vec::new(),
        }
    } else {
        Vec::new()
    };
    let deadlines = match args.get("deadlines") {
        Some(text) => parse_deadlines(text)?,
        None => Vec::new(),
    };
    let cfg = ServeConfig {
        policy,
        devices: args.get_parse("devices", d.devices)?,
        inflight: args.get_parse("inflight", d.inflight)?,
        total: args.get_parse("total", d.total)?,
        sort_fraction: args.get_parse("sort-fraction", d.sort_fraction)?,
        seed: args.get_parse("seed", d.seed)?,
        adaptive,
        resolve_check: args.get_parse("resolve-check", d.resolve_check)?,
        drift_threshold: args.get_parse("drift-threshold", d.drift_threshold)?,
        trigger,
        cusum_delta,
        cusum_h,
        stale_after,
        shards,
        sync_every: args.get_parse("sync-every", d.sync_every)?,
        priorities,
        deadlines,
        ..d
    };
    args.finish()?;

    let r = Coordinator::run(&cfg)?;
    let mut t = Table::new(
        format!(
            "serve: {} (inflight {}, {} devices{})",
            cfg.policy.name(),
            cfg.inflight,
            cfg.devices,
            if cfg.shards > 1 {
                format!(", {} shards", cfg.shards)
            } else {
                String::new()
            }
        ),
        &["metric", "value"],
    );
    t.row(vec!["requests".into(), r.served.to_string()]);
    t.row(vec!["throughput (req/s)".into(), format!("{:.1}", r.rps)]);
    t.row(vec!["sort p50 (ms)".into(), format!("{:.2}", r.sort_latency.quantile_s(0.5) * 1e3)]);
    t.row(vec!["sort p99 (ms)".into(), format!("{:.2}", r.sort_latency.quantile_s(0.99) * 1e3)]);
    t.row(vec!["nn p50 (ms)".into(), format!("{:.2}", r.nn_latency.quantile_s(0.5) * 1e3)]);
    t.row(vec!["nn p99 (ms)".into(), format!("{:.2}", r.nn_latency.quantile_s(0.99) * 1e3)]);
    t.row(vec!["nn batches".into(), r.batches.to_string()]);
    t.row(vec!["batch fill".into(), format!("{:.2}", r.batch_fill)]);
    t.row(vec![
        "flushes full/deadline/drain".into(),
        format!("{}/{}/{}", r.flushes[0], r.flushes[1], r.flushes[2]),
    ]);
    if cfg.shards > 1 {
        t.row(vec!["batched re-solves".into(), r.resolves.to_string()]);
    } else if cfg.adaptive {
        t.row(vec!["adaptive re-solves".into(), r.resolves.to_string()]);
    }
    if !cfg.priorities.is_empty() {
        t.row(vec!["priorities [sort, nn]".into(), format!("{:?}", cfg.priorities)]);
    }
    if !cfg.deadlines.is_empty() {
        t.row(vec![
            "deadline miss sort/nn".into(),
            format!(
                "{:.1}%/{:.1}%",
                r.deadline_miss_rate(0) * 100.0,
                r.deadline_miss_rate(1) * 100.0
            ),
        ]);
    }
    t.print();
    if let Some(mu_hat) = &r.mu_hat {
        let rows: Vec<String> = (0..mu_hat.types())
            .map(|i| {
                let cells: Vec<String> = (0..mu_hat.procs())
                    .map(|j| format!("{:.1}", mu_hat.rate(i, j)))
                    .collect();
                format!("[{}]", cells.join(", "))
            })
            .collect();
        println!("estimated μ̂: [{}] req/s", rows.join(", "));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mu_and_population_parsing() {
        let mu = parse_mu("20,15;3,8").unwrap();
        assert_eq!(mu.types(), 2);
        assert_eq!(mu.rate(1, 1), 8.0);
        assert!(parse_mu("20,x;3,8").is_err());
        assert_eq!(parse_populations("10, 10").unwrap(), vec![10, 10]);
        assert!(parse_populations("a").is_err());
    }

    #[test]
    fn dispatches_unknown_command() {
        let args = Args::parse(["wat".to_string()]).unwrap();
        assert!(run(&args).is_err());
    }

    #[test]
    fn scenario_command_runs_all_kinds_quickly() {
        for kind in ["phase_shift", "burst", "slow_drift", "abrupt_flip", "priority_mix"] {
            let line = format!(
                "scenario --kind {kind} --policy grin --phases 3 \
                 --completions 150 --warmup 20 --resolve every_phase"
            );
            let args =
                Args::parse(line.split_whitespace().map(String::from)).unwrap();
            run(&args).unwrap();
        }
        // Unknown kind is rejected.
        let args = Args::parse(
            "scenario --kind steady".split_whitespace().map(String::from),
        )
        .unwrap();
        assert!(run(&args).is_err());
    }

    #[test]
    fn scenario_cusum_trigger_runs_and_gates_its_flags() {
        // The CUSUM trigger drives an adaptive scenario end to end.
        let line = "scenario --kind abrupt_flip --policy grin --phases 3 \
                    --completions 150 --warmup 20 --resolve adaptive \
                    --trigger cusum --cusum-h 2.0 --cusum-delta 0.25 \
                    --stale-after 500";
        let args = Args::parse(line.split_whitespace().map(String::from)).unwrap();
        run(&args).unwrap();
        // Unknown trigger is rejected.
        let args = Args::parse(
            "scenario --kind burst --trigger vibes"
                .split_whitespace()
                .map(String::from),
        )
        .unwrap();
        assert!(run(&args).is_err());
        // CUSUM knobs without a CUSUM arm are flagged, not ignored.
        let args = Args::parse(
            "scenario --kind burst --phases 3 --completions 100 --warmup 10 \
             --cusum-h 9.0"
                .split_whitespace()
                .map(String::from),
        )
        .unwrap();
        assert!(run(&args).is_err());
    }

    #[test]
    fn scenario_sharded_resolve_and_compare_run() {
        // The sharded resolve mode drives a scenario end to end...
        let line = "scenario --kind phase_shift --policy grin --phases 3 \
                    --completions 150 --warmup 20 --resolve sharded --shards 2 \
                    --sync-every 60";
        let args = Args::parse(line.split_whitespace().map(String::from)).unwrap();
        run(&args).unwrap();
        // ...and --compare carries it as the fourth arm, with the
        // replicated A/B summary on top.
        let line = "scenario --kind slow_drift --policy grin --phases 3 \
                    --completions 120 --warmup 20 --n 8 --compare --reps 2";
        let args = Args::parse(line.split_whitespace().map(String::from)).unwrap();
        run(&args).unwrap();
    }

    #[test]
    fn scenario_priority_flags_gate_and_run() {
        // priority_mix + explicit priorities/deadlines runs end to end
        // under the adaptive resolve, reporting the class-0 line.
        let line = "scenario --kind priority_mix --mu 30,3.5;31,16 --policy grin \
                    --phases 2 --completions 150 --warmup 20 --resolve adaptive \
                    --priorities 4,1 --deadlines 1.0,0";
        let args = Args::parse(line.split_whitespace().map(String::from)).unwrap();
        run(&args).unwrap();
        // --priorities on a policy that cannot consume the weighted
        // solve is flagged as unknown, not silently ignored.
        let args = Args::parse(
            "scenario --kind burst --policy cab --phases 3 --completions 100 \
             --warmup 10 --resolve every_phase --priorities 4,1"
                .split_whitespace()
                .map(String::from),
        )
        .unwrap();
        let msg = run(&args).unwrap_err().to_string();
        assert!(msg.contains("unknown flag"), "{msg}");
        // Malformed values are parse errors.
        let args = Args::parse(
            "scenario --kind priority_mix --phases 2 --completions 50 --warmup 5 \
             --priorities 4,x"
                .split_whitespace()
                .map(String::from),
        )
        .unwrap();
        assert!(run(&args).unwrap_err().to_string().contains("bad priority"));
        // --compare under a non-GrIn policy has no priority arm, so
        // --priorities is flagged there too — never silently dropped.
        let args = Args::parse(
            "scenario --kind burst --policy cab --phases 3 --completions 100 \
             --warmup 10 --compare --reps 1 --priorities 4,1"
                .split_whitespace()
                .map(String::from),
        )
        .unwrap();
        let msg = run(&args).unwrap_err().to_string();
        assert!(msg.contains("unknown flag"), "{msg}");
        // --deadlines applies under any policy (pure accounting).
        let line = "scenario --kind burst --policy cab --phases 3 --completions 100 \
                    --warmup 10 --resolve every_phase --deadlines 5.0,0";
        let args = Args::parse(line.split_whitespace().map(String::from)).unwrap();
        run(&args).unwrap();
    }

    #[test]
    fn serve_flag_conflicts_are_rejected() {
        // --resolve-check is the single-leader cadence knob.
        let args = Args::parse(
            "serve --shards 2 --devices 4 --resolve-check 16"
                .split_whitespace()
                .map(String::from),
        )
        .unwrap();
        assert!(run(&args).is_err());
        // An explicit non-GrIn policy cannot drive the sharded plane.
        let args = Args::parse(
            "serve --shards 2 --devices 4 --policy cab --total 10"
                .split_whitespace()
                .map(String::from),
        )
        .unwrap();
        assert!(run(&args).is_err());
        // --trigger only applies to the adaptive/sharded estimation
        // loops: without either it is flagged, not silently ignored.
        let args = Args::parse(
            "serve --total 10 --trigger cusum"
                .split_whitespace()
                .map(String::from),
        )
        .unwrap();
        assert!(run(&args).is_err());
        // --priorities without a weighted-GrIn consumer (default policy
        // is CAB) is flagged as unknown, not silently ignored.
        let args = Args::parse(
            "serve --total 10 --priorities 4,1"
                .split_whitespace()
                .map(String::from),
        )
        .unwrap();
        let msg = run(&args).unwrap_err().to_string();
        assert!(msg.contains("unknown flag"), "{msg}");
        // On the GrIn policy it is consumed: the error here is the
        // total-0 validation, not an unknown flag.
        let args = Args::parse(
            "serve --policy grin --priorities 4,1 --deadlines 0.05,0.1 --total 0"
                .split_whitespace()
                .map(String::from),
        )
        .unwrap();
        let msg = run(&args).unwrap_err().to_string();
        assert!(!msg.contains("unknown flag"), "{msg}");
    }

    #[test]
    fn trigger_flags_gate_on_the_estimating_paths() {
        // serve: --trigger/--stale-after are consumed on the adaptive
        // path — the error here is the total-0 validation, not an
        // unknown flag.
        let args = Args::parse(
            "serve --adaptive --trigger cusum --stale-after 500 --total 0"
                .split_whitespace()
                .map(String::from),
        )
        .unwrap();
        let msg = run(&args).unwrap_err().to_string();
        assert!(!msg.contains("unknown flag"), "{msg}");
        // scenario: --trigger on a non-estimating resolve mode is
        // flagged, not silently ignored.
        let args = Args::parse(
            "scenario --kind burst --resolve static --trigger cusum"
                .split_whitespace()
                .map(String::from),
        )
        .unwrap();
        let msg = run(&args).unwrap_err().to_string();
        assert!(msg.contains("unknown flag"), "{msg}");
        // ...and so is --stale-after.
        let args = Args::parse(
            "scenario --kind burst --resolve every_phase --stale-after 10"
                .split_whitespace()
                .map(String::from),
        )
        .unwrap();
        let msg = run(&args).unwrap_err().to_string();
        assert!(msg.contains("unknown flag"), "{msg}");
    }

    #[test]
    fn sweep_json_snapshot_is_thread_count_invariant() {
        let dir = std::env::temp_dir();
        // Pid-suffixed so concurrent test processes don't race on the files.
        let pid = std::process::id();
        let p1 = dir.join(format!("hetsched_sweep_t1_{pid}.json"));
        let p4 = dir.join(format!("hetsched_sweep_t4_{pid}.json"));
        for (threads, path) in [(1, &p1), (4, &p4)] {
            let line = format!(
                "sweep --quick --reps 2 --measure 200 --warmup 20 \
                 --threads {threads} --json {}",
                path.display()
            );
            let args = Args::parse(line.split_whitespace().map(String::from)).unwrap();
            run(&args).unwrap();
        }
        let a = std::fs::read_to_string(&p1).unwrap();
        let b = std::fs::read_to_string(&p4).unwrap();
        // The snapshot embeds per-cell f64 bit patterns and omits the
        // thread count, so the CI determinism gate can compare files
        // byte for byte.
        assert_eq!(a, b, "sweep snapshot depends on thread count");
        let doc = crate::config::json::Json::parse(&a).unwrap();
        assert_eq!(doc.req("cells").unwrap().as_arr().unwrap().len(), 15);
        let _ = std::fs::remove_file(&p1);
        let _ = std::fs::remove_file(&p4);
    }

    #[test]
    fn sweep_command_runs_replicated_quick_grid() {
        let args = Args::parse(
            "sweep --quick --reps 2 --measure 200 --warmup 20 --threads 2"
                .split_whitespace()
                .map(String::from),
        )
        .unwrap();
        run(&args).unwrap();
        // Bad policy list is rejected.
        let args = Args::parse(
            "sweep --policies cab,fifo".split_whitespace().map(String::from),
        )
        .unwrap();
        assert!(run(&args).is_err());
    }

    #[test]
    fn solve_and_classify_run() {
        let args = Args::parse(
            "solve --mu 20,15;3,8 --populations 6,6 --solver grin"
                .split_whitespace()
                .map(String::from),
        )
        .unwrap();
        run(&args).unwrap();
        let args = Args::parse(
            "classify --mu 20,15;3,8".split_whitespace().map(String::from),
        )
        .unwrap();
        run(&args).unwrap();
    }
}
