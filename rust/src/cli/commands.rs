//! The `hetsched` launcher subcommands.
//!
//! ```text
//! hetsched simulate  --config spec.json | --policy cab --eta 0.5 ...
//!                    [--objective energy --power-scenario exponent:0.5
//!                     --power-coeff k --idle-power f]
//! hetsched sweep     --dist exp --n 20 [--policies cab,bf,rd,jsq,lb]
//!                    [--reps 16 --threads 0 --quick --json out.json]
//! hetsched solve     --mu "20,15;3,8" --populations 10,10 [--solver grin]
//! hetsched scenario  --kind slow_drift --policy grin [--compare --reps 4]
//!                    [--resolve sharded --shards N --sync-every M]
//!                    [--trigger cusum --cusum-h 4.0 --cusum-delta 0.25]
//!                    [--priorities 4,1 --deadlines 1.0,0 --threads T]
//!                    [--objective energy|edp|tpw:0.9 --power-scenario S]
//!                    [--kind churn --churn 0.3 --churn-limp 0.25]
//!                    [--fault-plan "down:0@5;up:0@25" --backup-budget B]
//! hetsched platform  --case p2_biased --eta 0.5 --policy cab
//! hetsched serve     --policy cab --inflight 16 --total 400 [--adaptive]
//!                    [--devices L --shards N --sync-every M]
//!                    [--frontend-threads N --batch B --batch-deadline MS]
//!                    [--trigger cusum --cusum-h 4.0 --cusum-delta 0.25]
//!                    [--priorities 4,1 --deadlines 0.05,0.1]
//!                    [--objective energy|edp|tpw:0.9 --power-scenario S]
//! hetsched classify  --mu "20,15;3,8"
//! ```

// srclint: allow-file(index-reachable) — table rows are built and indexed in the same function over fixed column sets

use crate::config::schema::{ExperimentSpec, ScenarioSpec};
use crate::coordinator::{Coordinator, ServeConfig};
use crate::error::{Error, Result};
use crate::model::affinity::AffinityMatrix;
use crate::model::energy::PowerScenario;
use crate::model::objective::{Objective, PowerProfile};
use crate::model::throughput::{x_max_theoretical, x_of_state};
use crate::platform::bench_rig::{cases, run_platform, PlatformConfig};
use crate::platform::measure_rates;
use crate::policy::PolicyKind;
use crate::report::Table;
use crate::sim::distribution::Distribution;
use crate::sim::engine::{ClosedNetwork, SimConfig};
use crate::sim::workload;
use crate::solver::exhaustive::ExhaustiveSolver;
use crate::solver::slsqp::Slsqp;

use super::parser::{Args, Knob, Knobs};

/// The declarative knob registry: every conditionally-valid flag is
/// declared once, bound to the capability that makes it meaningful.
/// Commands build a [`Knobs`] view over this table and enable the
/// capabilities of the current invocation; a flag whose capability is
/// disabled stays unconsumed, so [`Args::finish`] produces the exact
/// unknown-flag error the old hand-rolled per-command gating did.
const KNOBS: &[Knob] = &[
    // Objective/power axis: needs a solve that can score it.
    Knob { flag: "objective", cap: "objective" },
    Knob { flag: "power-scenario", cap: "objective" },
    Knob { flag: "power-coeff", cap: "objective" },
    Knob { flag: "idle-power", cap: "objective" },
    // Change detection: only the estimating resolve/serve loops.
    Knob { flag: "trigger", cap: "estimating" },
    Knob { flag: "stale-after", cap: "estimating" },
    // CUSUM tuning: only when a CUSUM arm runs.
    Knob { flag: "cusum-h", cap: "cusum" },
    Knob { flag: "cusum-delta", cap: "cusum" },
    // Sharded control plane.
    Knob { flag: "shards", cap: "sharded" },
    Knob { flag: "sync-every", cap: "sharded" },
    // Priority weighting: needs a weighted-GrIn consumer.
    Knob { flag: "priorities", cap: "weighted" },
    // Churn-shape knobs: only the churn scenario builds a schedule
    // from them.
    Knob { flag: "churn", cap: "churn" },
    Knob { flag: "churn-limp", cap: "churn" },
    // Fault injection: any scenario kind can carry an explicit plan
    // (commands without a fault path leave these unconsumed).
    Knob { flag: "fault-plan", cap: "faults" },
    Knob { flag: "backup-budget", cap: "faults" },
    // Replication fan-out of `scenario --compare`.
    Knob { flag: "reps", cap: "compare" },
    Knob { flag: "threads", cap: "compare" },
    // Concurrent serving front end: router-level batching knobs only
    // mean something once --frontend-threads turns the front end on.
    Knob { flag: "batch", cap: "frontend" },
    Knob { flag: "batch-deadline", cap: "frontend" },
];

/// Read the four energy knobs (`--objective`, `--power-scenario`,
/// `--power-coeff`, `--idle-power`) through a gated [`Knobs`] view and
/// validate the result.  When the view's "objective" capability is
/// disabled every knob reads as its default — and a stray flag surfaces
/// through `finish()`.
fn parse_power_knobs(knobs: &Knobs<'_>) -> Result<(Objective, PowerProfile)> {
    let objective = match knobs.get("objective") {
        Some(name) => Objective::parse(name)?,
        None => Objective::Throughput,
    };
    let scenario = match knobs.get("power-scenario") {
        Some(name) => PowerScenario::parse(name)?,
        None => PowerScenario::Proportional,
    };
    let coeff: f64 = knobs.get_parse("power-coeff", 1.0)?;
    let idle: f64 = knobs.get_parse("idle-power", 0.0)?;
    let profile = PowerProfile::new(coeff, scenario).with_idle(idle);
    profile.validate()?;
    objective.validate()?;
    Ok((objective, profile))
}

/// Top-level usage text.
pub const USAGE: &str = "\
hetsched — task scheduling for heterogeneous multicore systems (CAB + GrIn)

USAGE: hetsched <COMMAND> [FLAGS]

COMMANDS:
  simulate   run one closed-network simulation (JSON spec or flags;
             --objective energy|edp|tpw:<frac> switches the GrIn solve
             off the throughput axis, --power-scenario
             constant|proportional|exponent:<alpha> with --power-coeff k
             sets the 𝒫 = k·μ^α model and --idle-power f adds a
             per-processor idle floor)
  sweep      η-sweep of all policies (the Figs. 4–7 experiment) with R
             seeded replications per cell fanned across cores; reports
             mean X ± 95% CI (--reps, --threads, --quick, --json FILE
             writes a bit-exact snapshot for the CI determinism gate)
  solve      solve Eq. 28 for a μ matrix (grin | opt | slsqp | cab)
  scenario   run a non-stationary scenario (phase_shift | burst |
             slow_drift | abrupt_flip | priority_mix | churn |
             saturation) under a
             resolve mode (static | every_phase | adaptive | sharded),
             or --compare all modes side by side plus CUSUM-triggered,
             priority-weighted and energy-objective adaptive arms
             (--reps/--threads replicate each arm; --shards/--sync-every
             tune the sharded control plane; --trigger threshold|cusum
             with --cusum-h/--cusum-delta picks the change detector,
             --stale-after tunes stale-cell demotion; --priorities a,b
             weights the GrIn solve per class, --deadlines x,y adds
             soft-deadline miss accounting, 0 = none; --objective
             energy|edp|tpw:<frac> re-aims the GrIn solve with
             --power-scenario/--power-coeff/--idle-power setting the
             power model; --kind churn injects device failures with
             --churn <outage frac> and --churn-limp <slow-node factor>,
             or give any kind an explicit --fault-plan
             \"down:J@T;up:J@T;limp:JxF@T\" schedule, with
             --backup-budget B capping re-dispatch backups)
  classify   classify a 2×2 μ matrix into its Table-1 regime
  platform   run the §7 platform emulation (needs `make artifacts`)
  serve      run the serving coordinator demo (--adaptive for live
             re-solve against estimated rates, --trigger cusum for
             change-point-triggered re-solves; --devices L --shards N
             for the sharded multi-leader plane; --frontend-threads N
             for the lock-free concurrent router front end with
             --batch B/--batch-deadline MS coalescing same-class
             requests behind one steering decision; --priorities a,b
             for priority-weighted GrIn serving, --deadlines x,y for
             per-class latency-deadline miss rates; --objective
             energy|edp|tpw:<frac> re-aims the GrIn-backed solve, with
             --power-scenario/--power-coeff/--idle-power as in simulate)
  help       show this text

Run `hetsched <COMMAND> --help` for per-command flags.";

/// Parse "a,b;c,d" into an affinity matrix.
pub fn parse_mu(text: &str) -> Result<AffinityMatrix> {
    let rows: Vec<Vec<f64>> = text
        .split(';')
        .map(|row| {
            row.split(',')
                .map(|c| {
                    c.trim()
                        .parse::<f64>()
                        .map_err(|_| Error::Parse(format!("bad μ entry '{c}'")))
                })
                .collect()
        })
        .collect::<Result<_>>()?;
    AffinityMatrix::from_rows(&rows)
}

/// Parse "10,10" into populations.
pub fn parse_populations(text: &str) -> Result<Vec<u32>> {
    text.split(',')
        .map(|c| {
            c.trim()
                .parse::<u32>()
                .map_err(|_| Error::Parse(format!("bad population '{c}'")))
        })
        .collect()
}

/// Parse "4,1" into per-class integer priorities.
pub fn parse_priorities(text: &str) -> Result<Vec<u32>> {
    text.split(',')
        .map(|c| {
            c.trim()
                .parse::<u32>()
                .map_err(|_| Error::Parse(format!("bad priority '{c}'")))
        })
        .collect()
}

/// Parse "1.0,0" into per-class soft deadlines (seconds; 0 = none).
pub fn parse_deadlines(text: &str) -> Result<Vec<f64>> {
    text.split(',')
        .map(|c| {
            c.trim()
                .parse::<f64>()
                .map_err(|_| Error::Parse(format!("bad deadline '{c}'")))
        })
        .collect()
}

/// Entry point called by `main`.
pub fn run(args: &Args) -> Result<()> {
    match args.subcommand() {
        None | Some("help") => {
            println!("{USAGE}");
            Ok(())
        }
        Some("simulate") => cmd_simulate(args),
        Some("sweep") => cmd_sweep(args),
        Some("solve") => cmd_solve(args),
        Some("scenario") => cmd_scenario(args),
        Some("classify") => cmd_classify(args),
        Some("platform") => cmd_platform(args),
        Some("serve") => cmd_serve(args),
        Some(other) => Err(Error::Config(format!(
            "unknown command '{other}' — try `hetsched help`"
        ))),
    }
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let spec = if let Some(path) = args.get("config") {
        ExperimentSpec::from_file(path)?
    } else {
        let mu = parse_mu(args.get("mu").unwrap_or("20,15;3,8"))?;
        let pops = parse_populations(args.get("populations").unwrap_or("10,10"))?;
        let policy = PolicyKind::parse(args.get("policy").unwrap_or("cab"))?;
        let mut sim = SimConfig::paper_default(pops);
        sim.dist = Distribution::parse(args.get("dist").unwrap_or("exp"))?;
        sim.seed = args.get_parse("seed", sim.seed)?;
        sim.warmup = args.get_parse("warmup", sim.warmup)?;
        sim.measure = args.get_parse("measure", sim.measure)?;
        // The energy knobs are always consumable here: metering applies
        // under every policy, and a non-throughput --objective on a
        // policy that cannot score it fails loudly at prepare time.
        let mut knobs = args.knobs(KNOBS);
        knobs.enable("objective");
        let (objective, power) = parse_power_knobs(&knobs)?;
        sim.objective = objective;
        sim.power = power.scenario;
        sim.power_coeff = power.coeff;
        sim.idle_power = power.idle_power;
        ExperimentSpec { mu, policy, sim }
    };
    args.finish()?;

    let net = ClosedNetwork::new(&spec.mu, spec.sim.clone())?;
    let mut policy = spec.policy.build();
    let r = net.run(policy.as_mut())?;
    let mut t = Table::new(
        format!("simulate: {} on {:?}", spec.policy.name(), spec.sim.dist.name()),
        &["metric", "value"],
    );
    t.row(vec!["X (tasks/s)".into(), format!("{:.4}", r.throughput)]);
    t.row(vec!["E[T] (s)".into(), format!("{:.4}", r.mean_response)]);
    t.row(vec!["E[ℰ]".into(), format!("{:.4}", r.mean_energy)]);
    t.row(vec!["EDP".into(), format!("{:.4}", r.edp)]);
    t.row(vec!["X·E[T] (≈N)".into(), format!("{:.4}", r.little_product)]);
    t.row(vec!["completions".into(), r.completed.to_string()]);
    if !spec.sim.objective.is_throughput() {
        t.row(vec!["objective".into(), spec.sim.objective.name().into()]);
    }
    t.print();
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<()> {
    use crate::sim::replicate::{run_cells, ReplicationPlan, SimCell};

    let mu = parse_mu(args.get("mu").unwrap_or("20,15;3,8"))?;
    let n: u32 = args.get_parse("n", 20u32)?;
    let dist = Distribution::parse(args.get("dist").unwrap_or("exp"))?;
    let seed: u64 = args.get_parse("seed", 7u64)?;
    let quick = args.switch("quick");
    let default_measure: u64 = if quick { 2_000 } else { 20_000 };
    let measure: u64 = args.get_parse("measure", default_measure)?;
    let warmup: u64 = args.get_parse("warmup", if quick { 200 } else { 2_000 })?;
    let reps: u32 = args.get_parse("reps", if quick { 4 } else { 16 })?;
    let threads: usize = args.get_parse("threads", 0usize)?;
    let json_path = args.get("json").map(str::to_string);
    let kinds: Vec<PolicyKind> = match args.get("policies") {
        Some(list) => list
            .split(',')
            .map(PolicyKind::parse)
            .collect::<Result<_>>()?,
        None => PolicyKind::five_two_type().to_vec(),
    };
    args.finish()?;

    let etas: Vec<f64> = if quick {
        vec![0.2, 0.5, 0.8]
    } else {
        workload::eta_grid().to_vec()
    };
    let mut cells = Vec::with_capacity(etas.len() * kinds.len());
    for &eta in &etas {
        let (n1, n2) = workload::split_populations(n, eta);
        for kind in &kinds {
            let mut sim = SimConfig::paper_default(vec![n1, n2]);
            sim.dist = dist;
            sim.seed = seed;
            sim.warmup = warmup;
            sim.measure = measure;
            cells.push(SimCell {
                label: format!("eta={eta:.1} {}", kind.name()),
                mu: mu.clone(),
                sim,
                policy: *kind,
            });
        }
    }
    let plan = ReplicationPlan { reps, threads, base_seed: seed };
    // srclint: allow(instant-now) — CLI reports real sweep wall time to the terminal user.
    let t0 = std::time::Instant::now();
    let stats = run_cells(&cells, &plan)?;
    let wall = t0.elapsed().as_secs_f64();

    let mut headers: Vec<&str> = vec!["eta"];
    let names: Vec<String> = kinds.iter().map(|k| k.name().to_string()).collect();
    headers.extend(names.iter().map(|s| s.as_str()));
    let mut t = Table::new(
        format!("throughput sweep, dist={}, N={n}, R={reps} (mean ± 95% CI)", dist.name()),
        &headers,
    );
    for (ei, eta) in etas.iter().enumerate() {
        let mut row = vec![format!("{eta:.1}")];
        for ki in 0..kinds.len() {
            let s = &stats[ei * kinds.len() + ki];
            row.push(format!("{:.3} ± {:.3}", s.mean_x, s.ci95_x));
        }
        t.row(row);
    }
    t.print();
    let runs = cells.len() as u64 * reps as u64;
    println!(
        "{} cells × {} reps = {} replications on {} threads in {:.2}s ({:.1} runs/s)",
        cells.len(),
        reps,
        runs,
        plan.effective_threads(),
        wall,
        runs as f64 / wall.max(1e-9)
    );
    if let Some(path) = json_path {
        // Bit-exact per-cell snapshot for the CI determinism gate: the
        // file must be byte-identical across thread counts (seeds derive
        // from (base, cell, rep) alone and slots fix the fp sum order),
        // so the recorded thread count is deliberately omitted.
        use crate::config::json::Json;
        let cell_docs: Vec<Json> = stats
            .iter()
            .map(|s| {
                Json::Obj(vec![
                    ("label".to_string(), Json::Str(s.label.clone())),
                    ("mean_x".to_string(), Json::Num(s.mean_x)),
                    ("mean_x_bits".to_string(), Json::Str(format!("{:016x}", s.mean_x.to_bits()))),
                    ("ci95_x".to_string(), Json::Num(s.ci95_x)),
                    ("ci95_x_bits".to_string(), Json::Str(format!("{:016x}", s.ci95_x.to_bits()))),
                    ("mean_energy".to_string(), Json::Num(s.mean_energy)),
                    (
                        "mean_energy_bits".to_string(),
                        Json::Str(format!("{:016x}", s.mean_energy.to_bits())),
                    ),
                    ("mean_edp".to_string(), Json::Num(s.mean_edp)),
                    (
                        "mean_edp_bits".to_string(),
                        Json::Str(format!("{:016x}", s.mean_edp.to_bits())),
                    ),
                ])
            })
            .collect();
        let doc = Json::Obj(vec![
            (
                "sweep".to_string(),
                Json::Obj(vec![
                    ("n".to_string(), Json::Num(f64::from(n))),
                    ("reps".to_string(), Json::Num(f64::from(reps))),
                    // u64 seeds can exceed f64's exact-integer range.
                    ("seed".to_string(), Json::Str(seed.to_string())),
                    ("dist".to_string(), Json::Str(dist.name().to_string())),
                ]),
            ),
            ("cells".to_string(), Json::Arr(cell_docs)),
        ]);
        std::fs::write(&path, doc.to_string_compact())?;
        println!("wrote {path}");
    }
    Ok(())
}

fn cmd_solve(args: &Args) -> Result<()> {
    let mu = parse_mu(
        args.get("mu")
            .ok_or_else(|| Error::Config("--mu is required".into()))?,
    )?;
    let pops = parse_populations(
        args.get("populations")
            .ok_or_else(|| Error::Config("--populations is required".into()))?,
    )?;
    let solver = args.get("solver").unwrap_or("grin").to_string();
    args.finish()?;

    match solver.as_str() {
        "grin" => {
            let sol = crate::policy::grin::solve(&mu, &pops)?;
            println!("GrIn: X = {:.6} after {} moves", sol.throughput, sol.moves);
            print!("{}", sol.state);
        }
        "opt" => {
            let sol = ExhaustiveSolver.solve(&mu, &pops)?;
            println!("Opt: X = {:.6} over {} states", sol.throughput, sol.evaluated);
            print!("{}", sol.state);
        }
        "slsqp" => {
            let sol = Slsqp::default().solve(&mu, &pops)?;
            println!(
                "SLSQP: X = {:.6} in {} iterations (converged: {})",
                sol.throughput, sol.iterations, sol.converged
            );
        }
        "cab" => {
            let (regime, target) = crate::policy::cab::Cab::target_state(&mu, &pops)?;
            println!(
                "CAB: regime {} → X = {:.6}",
                regime.name(),
                x_of_state(&mu, &target)
            );
            print!("{target}");
        }
        other => {
            return Err(Error::Config(format!(
                "unknown solver '{other}' (grin|opt|slsqp|cab)"
            )))
        }
    }
    Ok(())
}

fn cmd_scenario(args: &Args) -> Result<()> {
    use crate::sim::dynamic::{run_dynamic_report, DynamicConfig, FaultPlan, ResolveMode, Trigger};
    use crate::sim::workload::{churn_fault_plan, scenario_phases, ScenarioKind, ScenarioParams};

    let compare = args.switch("compare");
    let mut knobs = args.knobs(KNOBS);
    let (mu, policy, kind, dynamic) = if let Some(path) = args.get("config") {
        let spec = ScenarioSpec::from_file(path)?;
        (spec.mu, spec.policy, spec.kind, spec.dynamic)
    } else {
        let mu = parse_mu(args.get("mu").unwrap_or("20,15;3,8"))?;
        let policy = PolicyKind::parse(args.get("policy").unwrap_or("grin"))?;
        let kind = ScenarioKind::parse(args.get("kind").unwrap_or("slow_drift"))?;
        let d = ScenarioParams::default();
        let drift_to = match args.get("drift-to") {
            Some(list) => list
                .split(',')
                .map(|c| {
                    c.trim().parse::<f64>().map_err(|_| {
                        Error::Parse(format!("--drift-to: bad factor '{c}'"))
                    })
                })
                .collect::<Result<_>>()?,
            None => d.drift_to,
        };
        // The churn-shape knobs only feed the churn schedule builder;
        // any kind can carry an explicit fault plan.  Elsewhere both
        // sets surface as unknown flags.
        knobs.enable_if(kind == ScenarioKind::Churn, "churn");
        knobs.enable("faults");
        let p = ScenarioParams {
            n: args.get_parse("n", d.n)?,
            phases: args.get_parse("phases", d.phases)?,
            completions: args.get_parse("completions", d.completions)?,
            warmup: args.get_parse("warmup", d.warmup)?,
            low_eta: args.get_parse("low-eta", d.low_eta)?,
            high_eta: args.get_parse("high-eta", d.high_eta)?,
            burst_factor: args.get_parse("burst-factor", d.burst_factor)?,
            drift_to,
            churn_down: knobs.get_parse("churn", d.churn_down)?,
            churn_limp: knobs.get_parse("churn-limp", d.churn_limp)?,
            backup_budget: knobs.get_parse("backup-budget", d.backup_budget)?,
        };
        let mut dynamic = DynamicConfig::new(scenario_phases(kind, &p)?);
        // Failure/recovery schedule: an explicit --fault-plan wins; a
        // churn scenario without one gets the auto-built schedule that
        // matches its phases.  A nonzero --backup-budget overrides the
        // spec's own budget clause.
        if let Some(spec) = knobs.get("fault-plan") {
            let mut plan = FaultPlan::parse_spec(spec)?;
            plan.validate(mu.procs())?;
            if p.backup_budget > 0 {
                plan.backup_budget = p.backup_budget;
            }
            dynamic.faults = plan;
        } else if kind == ScenarioKind::Churn {
            dynamic.faults = churn_fault_plan(&mu, &p)?;
        }
        dynamic.resolve = ResolveMode::parse(args.get("resolve").unwrap_or("adaptive"))?;
        dynamic.dist = Distribution::parse(args.get("dist").unwrap_or("exp"))?;
        dynamic.seed = args.get_parse("seed", dynamic.seed)?;
        dynamic.drift.threshold = args.get_parse("drift-threshold", dynamic.drift.threshold)?;
        dynamic.drift.check_every = args.get_parse("check-every", dynamic.drift.check_every)?;
        // The capability gating lives in the KNOBS registry: enable
        // what this invocation supports and the gated lookups below
        // leave everything else unconsumed, so `finish()` flags stray
        // knobs instead of silently ignoring them.
        //
        // The trigger and staleness knobs only drive the estimating
        // resolve modes (adaptive/sharded, or any --compare, which runs
        // both).
        let estimating = matches!(
            dynamic.resolve,
            ResolveMode::Adaptive | ResolveMode::Sharded
        ) || compare;
        knobs.enable_if(estimating, "estimating");
        if estimating {
            dynamic.drift.trigger =
                Trigger::parse(knobs.get("trigger").unwrap_or("threshold"))?;
            dynamic.drift.stale_after =
                knobs.get_parse("stale-after", dynamic.drift.stale_after)?;
        }
        // Same rule, one level down, for the CUSUM knobs: they need a
        // CUSUM arm (--trigger cusum, or the --compare cusum arm).
        knobs.enable_if(dynamic.drift.trigger == Trigger::Cusum || compare, "cusum");
        dynamic.drift.cusum_h = knobs.get_parse("cusum-h", dynamic.drift.cusum_h)?;
        dynamic.drift.cusum_delta =
            knobs.get_parse("cusum-delta", dynamic.drift.cusum_delta)?;
        // Sharded knobs only apply when a sharded arm runs (--resolve
        // sharded or --compare).
        knobs.enable_if(dynamic.resolve == ResolveMode::Sharded || compare, "sharded");
        dynamic.shard.shards = knobs.get_parse("shards", dynamic.shard.shards)?;
        dynamic.shard.sync_every =
            knobs.get_parse("sync-every", dynamic.shard.sync_every)?;
        // --priorities and the objective knobs need a consumer of the
        // extended GrIn solve — the GrIn policy (directly, or via the
        // --compare priority/energy arms, which only exist under GrIn),
        // or a non-compare sharded run (the sharded plane always steers
        // by batched GrIn; under --compare the sharded arm is
        // deliberately plain).  The priority_mix scenario defaults to
        // the 4:1 split its canned schedule is designed around.
        let grin_backed = policy == PolicyKind::GrIn
            || (dynamic.resolve == ResolveMode::Sharded && !compare);
        knobs.enable_if(grin_backed, "weighted");
        knobs.enable_if(grin_backed, "objective");
        if grin_backed {
            let default_pri = if kind == ScenarioKind::PriorityMix { "4,1" } else { "" };
            let text = knobs.get("priorities").unwrap_or(default_pri);
            if !text.is_empty() {
                dynamic.priorities = parse_priorities(text)?;
            }
        }
        let (objective, power) = parse_power_knobs(&knobs)?;
        dynamic.objective = objective;
        dynamic.power = power;
        // Deadlines are pure accounting and apply under every resolve
        // mode/policy.
        if let Some(text) = args.get("deadlines") {
            dynamic.deadlines = parse_deadlines(text)?;
        }
        (mu, policy, kind, dynamic)
    };
    // Only meaningful with --compare: the registry leaves stray
    // `--reps`/`--threads` unconsumed otherwise.
    knobs.enable_if(compare, "compare");
    let reps: u32 = knobs.get_parse("reps", 4u32)?;
    let threads: usize = knobs.get_parse("threads", 0usize)?;
    args.finish()?;

    // The class whose throughput/miss lines are reported: the
    // highest-priority one (first on ties), class 0 when no priorities
    // are configured.
    let hi_class = |pri: &[u32]| -> usize {
        let top = pri.iter().copied().max().unwrap_or(0);
        pri.iter().position(|&p| p == top).unwrap_or(0)
    };
    // (per-phase X, mean X, re-solves, per-class X, per-class miss rate,
    //  E[ℰ]/task, EDP, tasks re-dispatched, downtime fraction)
    type ArmResult = (Vec<f64>, f64, u64, Vec<f64>, Vec<f64>, f64, f64, u64, f64);
    let run_arm = |mode: ResolveMode,
                   trigger: Trigger,
                   objective: Objective,
                   priorities: Vec<u32>|
     -> Result<ArmResult> {
        let mut cfg = dynamic.clone();
        cfg.resolve = mode;
        cfg.drift.trigger = trigger;
        cfg.objective = objective;
        cfg.priorities = priorities;
        let mut p = policy.build();
        let report = run_dynamic_report(&mu, &cfg, p.as_mut())?;
        let per_phase: Vec<f64> = report.phases.iter().map(|r| r.throughput).collect();
        let k = mu.types();
        Ok((
            per_phase,
            report.mean_throughput(),
            report.resolves,
            (0..k).map(|i| report.class_throughput(i)).collect(),
            (0..k).map(|i| report.deadline_miss_rate(i)).collect(),
            report.mean_energy(),
            report.mean_edp(),
            report.tasks_redispatched,
            report.mean_downtime_frac(),
        ))
    };

    if compare {
        // The comparison arms: the four resolve modes (adaptive under
        // the polled threshold trigger), the CUSUM-triggered adaptive
        // arm, and — under GrIn — the priority-weighted and
        // energy-objective adaptive arms; the sharded arm follows the
        // configured --trigger.  Independent runs, fanned across cores
        // through the replication runner's worker pool.
        struct Arm {
            mode: ResolveMode,
            trigger: Trigger,
            weighted: bool,
            objective: Objective,
            label: &'static str,
        }
        let arm = |mode, trigger, weighted, objective, label| Arm {
            mode,
            trigger,
            weighted,
            objective,
            label,
        };
        let arm_pri = if dynamic.priorities.is_empty() {
            vec![4, 1]
        } else {
            dynamic.priorities.clone()
        };
        let x = Objective::Throughput;
        let mut arms: Vec<Arm> = vec![
            arm(ResolveMode::Static, Trigger::Threshold, false, x, "static"),
            arm(ResolveMode::EveryPhase, Trigger::Threshold, false, x, "every_phase"),
            arm(ResolveMode::Adaptive, Trigger::Threshold, false, x, "adaptive"),
            arm(ResolveMode::Adaptive, Trigger::Cusum, false, x, "cusum"),
            arm(ResolveMode::Sharded, dynamic.drift.trigger, false, x, "sharded"),
        ];
        // The weighted solve and the objective axis are GrIn
        // extensions: under any other --policy the comparison stays at
        // the five plain arms.  An explicit --objective picks the
        // energy arm's axis; plain --compare defaults it to
        // energy-per-task.
        if policy == PolicyKind::GrIn {
            arms.push(arm(ResolveMode::Adaptive, Trigger::Threshold, true, x, "priority"));
            let energy_objective = if dynamic.objective.is_throughput() {
                Objective::EnergyPerTask
            } else {
                dynamic.objective
            };
            arms.push(arm(
                ResolveMode::Adaptive,
                Trigger::Threshold,
                false,
                energy_objective,
                "energy",
            ));
        }
        let results = crate::sim::replicate::parallel_map(&arms, 0, |_, a: &Arm| {
            run_arm(
                a.mode,
                a.trigger,
                a.objective,
                if a.weighted { arm_pri.clone() } else { Vec::new() },
            )
        })
        .into_iter()
        .collect::<Result<Vec<_>>>()?;
        // Label-addressed lookup: the optional GrIn arms keep their
        // position only relative to the five fixed leading arms.
        let pos = |label: &str| arms.iter().position(|a| a.label == label);
        let mut headers: Vec<&str> = vec!["phase"];
        headers.extend(arms.iter().map(|a| a.label));
        let mut t = Table::new(
            format!("scenario {} ({}): per-phase X by resolve mode", kind.name(), policy.name()),
            &headers,
        );
        for i in 0..dynamic.phases.len() {
            let mut row = vec![format!("{i}")];
            row.extend(results.iter().map(|r| format!("{:.4}", r.0[i])));
            t.row(row);
        }
        let mut mean_row = vec!["mean".to_string()];
        mean_row.extend(results.iter().map(|r| format!("{:.4}", r.1)));
        t.row(mean_row);
        t.print();
        let resolve_list: Vec<String> = arms
            .iter()
            .zip(&results)
            .map(|(a, r)| format!("{} {}", a.label, r.2))
            .collect();
        println!("re-solves: {}", resolve_list.join(" / "));
        if !dynamic.faults.is_empty() {
            // Per-arm fault response: how much work each mode had to
            // evacuate and how much device-time the plan took away.
            let churn_list: Vec<String> = arms
                .iter()
                .zip(&results)
                .map(|(a, r)| format!("{} {} @ {:.1}%", a.label, r.7, r.8 * 100.0))
                .collect();
            println!("re-dispatched @ downtime: {}", churn_list.join(" / "));
        }
        let mut summary = format!(
            "vs static mean X: adaptive {:.2}x, cusum {:.2}x, sharded {:.2}x",
            results[2].1 / results[0].1,
            results[3].1 / results[0].1,
            results[4].1 / results[0].1,
        );
        if let Some(pri) = pos("priority").map(|i| &results[i]) {
            summary.push_str(&format!(", priority {:.2}x", pri.1 / results[0].1));
        }
        if let Some(en) = pos("energy").map(|i| &results[i]) {
            summary.push_str(&format!(", energy {:.2}x", en.1 / results[0].1));
        }
        summary.push_str(&format!(
            " (oracle every_phase: {:.2}x)",
            results[1].1 / results[0].1
        ));
        println!("{summary}");
        if let Some(pri) = pos("priority").map(|i| &results[i]) {
            let h = hi_class(&arm_pri);
            let mut hi = format!(
                "high-priority class (class {h}) X: priority {:.4} vs adaptive {:.4} \
                 ({:.2}x at {:?})",
                pri.3[h],
                results[2].3[h],
                pri.3[h] / results[2].3[h].max(1e-12),
                arm_pri,
            );
            if !dynamic.deadlines.is_empty() {
                hi.push_str(&format!(
                    "; its deadline miss: priority {:.1}% vs adaptive {:.1}%",
                    pri.4[h] * 100.0,
                    results[2].4[h] * 100.0
                ));
            }
            println!("{hi}");
        }
        if let Some(i) = pos("energy") {
            // The energy arm trades throughput for joules: report both
            // sides against the plain adaptive arm it forked from.
            let (en, ad) = (&results[i], &results[2]);
            println!(
                "energy objective ({}): E[ℰ] {:.4}/task vs adaptive {:.4} ({:.2}x), \
                 X {:.4} vs {:.4}, EDP {:.4} vs {:.4}",
                arms[i].objective.name(),
                en.5,
                ad.5,
                ad.5 / en.5.max(1e-12),
                en.1,
                ad.1,
                en.6,
                ad.6,
            );
        }
        if reps > 1 {
            // Replicated A/B: R seeded replications per arm through the
            // replication runner (thread-count-independent aggregates).
            use crate::sim::replicate::{run_dynamic_cells, DynCell, ReplicationPlan};
            let cells: Vec<DynCell> = arms
                .iter()
                .map(|a| {
                    let mut cfg = dynamic.clone();
                    cfg.resolve = a.mode;
                    cfg.drift.trigger = a.trigger;
                    cfg.objective = a.objective;
                    cfg.priorities =
                        if a.weighted { arm_pri.clone() } else { Vec::new() };
                    DynCell {
                        label: a.label.to_string(),
                        mu: mu.clone(),
                        cfg,
                        policy,
                    }
                })
                .collect();
            let plan = ReplicationPlan { reps, threads, base_seed: dynamic.seed };
            let stats = run_dynamic_cells(&cells, &plan)?;
            let h = hi_class(&arm_pri);
            let with_miss = !dynamic.deadlines.is_empty();
            let x_col = format!("X(class {h})");
            let miss_col = format!("miss(class {h})");
            let with_faults = !dynamic.faults.is_empty();
            let mut headers = vec!["mode", "mean X", x_col.as_str()];
            if with_miss {
                headers.push(miss_col.as_str());
            }
            headers.push("E[ℰ]/task");
            if with_faults {
                headers.push("redisp/run");
                headers.push("down%");
            }
            headers.push("re-solves/run");
            let mut t = Table::new(
                format!("replicated comparison (R = {reps}, mean ± t-corrected 95% CI)"),
                &headers,
            );
            for s in &stats {
                let mut row = vec![
                    s.label.clone(),
                    format!("{:.4} ± {:.4}", s.mean_x, s.ci95_x),
                    format!("{:.4}", s.mean_class_x[h]),
                ];
                if with_miss {
                    row.push(format!("{:.1}%", s.mean_miss_rate[h] * 100.0));
                }
                row.push(format!("{:.4}", s.mean_energy));
                if with_faults {
                    row.push(format!("{:.1}", s.mean_redispatched));
                    row.push(format!("{:.1}%", s.mean_downtime_frac * 100.0));
                }
                row.push(format!("{:.1}", s.mean_resolves));
                t.row(row);
            }
            t.print();
        }
    } else {
        let (per_phase, mean, resolves, class_x, class_miss, energy, edp, redispatched, downtime) =
            run_arm(
                dynamic.resolve,
                dynamic.drift.trigger,
                dynamic.objective,
                dynamic.priorities.clone(),
            )?;
        let mut t = Table::new(
            format!(
                "scenario {} ({}, resolve {}, trigger {})",
                kind.name(),
                policy.name(),
                dynamic.resolve.name(),
                dynamic.drift.trigger.name()
            ),
            &["phase", "populations", "X (tasks/s)"],
        );
        for (i, x) in per_phase.iter().enumerate() {
            t.row(vec![
                format!("{i}"),
                format!("{:?}", dynamic.phases[i].populations),
                format!("{x:.4}"),
            ]);
        }
        t.print();
        println!("mean X = {mean:.4} tasks/s, {resolves} re-solves");
        if !dynamic.faults.is_empty() {
            println!(
                "fault plan: {} events, {redispatched} task(s) re-dispatched, \
                 downtime {:.1}%",
                dynamic.faults.events.len(),
                downtime * 100.0
            );
        }
        if !dynamic.objective.is_throughput() {
            println!(
                "objective {}: E[ℰ] = {energy:.4}/task, EDP = {edp:.4}",
                dynamic.objective.name()
            );
        }
        if !dynamic.priorities.is_empty() || !dynamic.deadlines.is_empty() {
            let h = hi_class(&dynamic.priorities);
            let mut line = format!("class-{h} X = {:.4} tasks/s", class_x[h]);
            if !dynamic.priorities.is_empty() {
                line.push_str(&format!(" (priorities {:?})", dynamic.priorities));
            }
            if !dynamic.deadlines.is_empty() {
                line.push_str(&format!(", deadline miss {:.1}%", class_miss[h] * 100.0));
            }
            println!("{line}");
        }
    }
    Ok(())
}

fn cmd_classify(args: &Args) -> Result<()> {
    let mu = parse_mu(
        args.get("mu")
            .ok_or_else(|| Error::Config("--mu is required".into()))?,
    )?;
    args.finish()?;
    let regime = mu.classify()?;
    println!("regime: {}", regime.name());
    println!(
        "CAB chooses: {}",
        if regime.is_biased() { "AF (accelerate-the-fastest)" } else { "BF (best-fit)" }
    );
    let (s11, s22) = crate::model::throughput::s_max(regime, 10, 10);
    println!("S_max at N1=N2=10: ({s11}, {s22})");
    println!(
        "X_max at N1=N2=10: {:.4}",
        x_max_theoretical(&mu, regime, 10, 10)
    );
    Ok(())
}

fn cmd_platform(args: &Args) -> Result<()> {
    let case = args.get("case").unwrap_or("general_symmetric").to_string();
    let eta: f64 = args.get_parse("eta", 0.5)?;
    let n: u32 = args.get_parse("n", 20u32)?;
    let policy = PolicyKind::parse(args.get("policy").unwrap_or("cab"))?;
    let cap: u32 = args.get_parse("rep-cap", 96u32)?;
    let measure: u64 = args.get_parse("measure", 60u64)?;
    let measure_runs: u32 = args.get_parse("measure-runs", 5u32)?;
    args.finish()?;

    eprintln!("calibrating kernel baselines...");
    let cal = crate::platform::calibrate(measure_runs)?;
    let devices = match case.as_str() {
        "general_symmetric" => cases::general_symmetric(&cal, cap),
        "p2_biased" => cases::p2_biased(&cal, cap),
        other => {
            return Err(Error::Config(format!(
                "unknown case '{other}' (general_symmetric|p2_biased)"
            )))
        }
    };
    eprintln!("measuring processing rates (Table 3 analog)...");
    let rates = measure_rates(&devices, measure_runs)?;
    let mut t = Table::new("measured rates (tasks/s)", &["task", "CPU", "GPU"]);
    for (i, name) in ["sort", "nn"].iter().enumerate() {
        t.row(vec![
            name.to_string(),
            format!("{:.2}", rates.mu.rate(i, 0)),
            format!("{:.2}", rates.mu.rate(i, 1)),
        ]);
    }
    t.print();
    println!("regime: {}", rates.mu.classify()?.name());

    let (n1, n2) = workload::split_populations(n, eta);
    let cfg = PlatformConfig {
        devices,
        populations: vec![n1, n2],
        warmup: n as u64,
        measure,
        seed: 11,
    };
    let mut p = policy.build();
    let r = run_platform(&cfg, &rates, p.as_mut())?;
    println!(
        "{}: X = {:.2} tasks/s, E[T] = {:.1} ms over {} tasks (η = {eta})",
        policy.name(),
        r.throughput,
        r.mean_response_s * 1e3,
        r.completions
    );
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let d = ServeConfig::default();
    let shards: usize = args.get_parse("shards", d.shards)?;
    let policy = match args.get("policy") {
        Some(name) => PolicyKind::parse(name)?,
        // Sharded serving always steers by batched GrIn (an explicit
        // conflicting --policy is rejected by Coordinator::run).
        None if shards > 1 => PolicyKind::GrIn,
        None => PolicyKind::Cab,
    };
    if shards > 1 && args.get("resolve-check").is_some() {
        return Err(Error::Config(
            "sharded serving syncs every --sync-every completions; \
             --resolve-check is the single-leader knob"
                .into(),
        ));
    }
    let adaptive = args.switch("adaptive");
    // Conditional knobs route through the KNOBS registry: --trigger and
    // --stale-after only drive the adaptive/sharded estimation loops,
    // the CUSUM pair needs a CUSUM trigger, and --priorities plus the
    // objective knobs need the GrIn-backed solve (GrIn policy or the
    // sharded plane, which always steers by batched GrIn).  A knob
    // whose capability is off stays unconsumed, so `finish()` flags it
    // instead of silently ignoring it.  --shards/--sync-every and
    // --deadlines are unconditional here and bypass the registry.
    let mut knobs = args.knobs(KNOBS);
    knobs.enable_if(adaptive || shards > 1, "estimating");
    let trigger = match knobs.get("trigger") {
        Some(t) => crate::sim::dynamic::Trigger::parse(t)?,
        None => d.trigger,
    };
    let stale_after = knobs.get_parse("stale-after", d.stale_after)?;
    knobs.enable_if(trigger == crate::sim::dynamic::Trigger::Cusum, "cusum");
    let cusum_delta = knobs.get_parse("cusum-delta", d.cusum_delta)?;
    let cusum_h = knobs.get_parse("cusum-h", d.cusum_h)?;
    let grin_backed = policy == PolicyKind::GrIn || shards > 1;
    knobs.enable_if(grin_backed, "weighted");
    knobs.enable_if(grin_backed, "objective");
    let priorities = match knobs.get("priorities") {
        Some(text) => parse_priorities(text)?,
        None => Vec::new(),
    };
    let (objective, power) = parse_power_knobs(&knobs)?;
    // The concurrent front end: --frontend-threads is unconditional
    // (like --shards), its batching knobs are gated on it.
    let frontend_threads: usize = args.get_parse("frontend-threads", d.frontend_threads)?;
    knobs.enable_if(frontend_threads > 0, "frontend");
    let router_batch: usize = knobs.get_parse("batch", d.router_batch)?;
    let batch_deadline = match knobs.get("batch-deadline") {
        Some(text) => {
            let ms: f64 = text
                .parse()
                .map_err(|_| Error::Parse(format!("bad batch-deadline '{text}'")))?;
            std::time::Duration::try_from_secs_f64(ms / 1e3)
                .map_err(|_| Error::Config(format!("batch-deadline {ms} ms out of range")))?
        }
        None => d.batch_deadline,
    };
    // --deadlines is pure latency accounting and applies to every mode.
    let deadlines = match args.get("deadlines") {
        Some(text) => parse_deadlines(text)?,
        None => Vec::new(),
    };
    let cfg = ServeConfig {
        policy,
        devices: args.get_parse("devices", d.devices)?,
        inflight: args.get_parse("inflight", d.inflight)?,
        total: args.get_parse("total", d.total)?,
        sort_fraction: args.get_parse("sort-fraction", d.sort_fraction)?,
        seed: args.get_parse("seed", d.seed)?,
        adaptive,
        resolve_check: args.get_parse("resolve-check", d.resolve_check)?,
        drift_threshold: args.get_parse("drift-threshold", d.drift_threshold)?,
        trigger,
        cusum_delta,
        cusum_h,
        stale_after,
        shards,
        sync_every: args.get_parse("sync-every", d.sync_every)?,
        priorities,
        deadlines,
        objective,
        power,
        frontend_threads,
        router_batch,
        batch_deadline,
        ..d
    };
    args.finish()?;

    let r = Coordinator::run(&cfg)?;
    let mut t = Table::new(
        format!(
            "serve: {} (inflight {}, {} devices{})",
            cfg.policy.name(),
            cfg.inflight,
            cfg.devices,
            if cfg.shards > 1 {
                format!(", {} shards", cfg.shards)
            } else if cfg.frontend_threads > 0 {
                format!(", {} frontend threads", cfg.frontend_threads)
            } else {
                String::new()
            }
        ),
        &["metric", "value"],
    );
    t.row(vec!["requests".into(), r.served.to_string()]);
    t.row(vec!["throughput (req/s)".into(), format!("{:.1}", r.rps)]);
    t.row(vec!["sort p50 (ms)".into(), format!("{:.2}", r.sort_latency.quantile_s(0.5) * 1e3)]);
    t.row(vec!["sort p99 (ms)".into(), format!("{:.2}", r.sort_latency.quantile_s(0.99) * 1e3)]);
    t.row(vec!["nn p50 (ms)".into(), format!("{:.2}", r.nn_latency.quantile_s(0.5) * 1e3)]);
    t.row(vec!["nn p99 (ms)".into(), format!("{:.2}", r.nn_latency.quantile_s(0.99) * 1e3)]);
    t.row(vec!["nn batches".into(), r.batches.to_string()]);
    t.row(vec!["batch fill".into(), format!("{:.2}", r.batch_fill)]);
    t.row(vec![
        "flushes full/deadline/drain".into(),
        format!("{}/{}/{}", r.flushes[0], r.flushes[1], r.flushes[2]),
    ]);
    if cfg.shards > 1 {
        t.row(vec!["batched re-solves".into(), r.resolves.to_string()]);
    } else if cfg.adaptive {
        t.row(vec!["adaptive re-solves".into(), r.resolves.to_string()]);
    }
    if cfg.frontend_threads > 0 {
        t.row(vec!["route decisions".into(), r.route_decisions.to_string()]);
        if cfg.router_batch > 1 {
            t.row(vec![
                "decision amortization".into(),
                format!("{:.2}", r.served as f64 / r.route_decisions.max(1) as f64),
            ]);
        }
    }
    if !cfg.priorities.is_empty() {
        t.row(vec!["priorities [sort, nn]".into(), format!("{:?}", cfg.priorities)]);
    }
    if !cfg.objective.is_throughput() {
        t.row(vec!["objective".into(), cfg.objective.name().into()]);
        t.row(vec!["E[ℰ] (J/req)".into(), format!("{:.4}", r.mean_energy)]);
        t.row(vec!["EDP".into(), format!("{:.4}", r.edp)]);
    }
    if !cfg.deadlines.is_empty() {
        t.row(vec![
            "deadline miss sort/nn".into(),
            format!(
                "{:.1}%/{:.1}%",
                r.deadline_miss_rate(0) * 100.0,
                r.deadline_miss_rate(1) * 100.0
            ),
        ]);
    }
    t.print();
    if let Some(mu_hat) = &r.mu_hat {
        let rows: Vec<String> = (0..mu_hat.types())
            .map(|i| {
                let cells: Vec<String> = (0..mu_hat.procs())
                    .map(|j| format!("{:.1}", mu_hat.rate(i, j)))
                    .collect();
                format!("[{}]", cells.join(", "))
            })
            .collect();
        println!("estimated μ̂: [{}] req/s", rows.join(", "));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mu_and_population_parsing() {
        let mu = parse_mu("20,15;3,8").unwrap();
        assert_eq!(mu.types(), 2);
        assert_eq!(mu.rate(1, 1), 8.0);
        assert!(parse_mu("20,x;3,8").is_err());
        assert_eq!(parse_populations("10, 10").unwrap(), vec![10, 10]);
        assert!(parse_populations("a").is_err());
    }

    #[test]
    fn dispatches_unknown_command() {
        let args = Args::parse(["wat".to_string()]).unwrap();
        assert!(run(&args).is_err());
    }

    #[test]
    fn scenario_command_runs_all_kinds_quickly() {
        for kind in [
            "phase_shift",
            "burst",
            "slow_drift",
            "abrupt_flip",
            "priority_mix",
            "churn",
            "saturation",
        ] {
            let line = format!(
                "scenario --kind {kind} --policy grin --phases 3 \
                 --completions 150 --warmup 20 --resolve every_phase"
            );
            let args =
                Args::parse(line.split_whitespace().map(String::from)).unwrap();
            run(&args).unwrap();
        }
        // Unknown kind is rejected.
        let args = Args::parse(
            "scenario --kind steady".split_whitespace().map(String::from),
        )
        .unwrap();
        assert!(run(&args).is_err());
    }

    #[test]
    fn scenario_cusum_trigger_runs_and_gates_its_flags() {
        // The CUSUM trigger drives an adaptive scenario end to end.
        let line = "scenario --kind abrupt_flip --policy grin --phases 3 \
                    --completions 150 --warmup 20 --resolve adaptive \
                    --trigger cusum --cusum-h 2.0 --cusum-delta 0.25 \
                    --stale-after 500";
        let args = Args::parse(line.split_whitespace().map(String::from)).unwrap();
        run(&args).unwrap();
        // Unknown trigger is rejected.
        let args = Args::parse(
            "scenario --kind burst --trigger vibes"
                .split_whitespace()
                .map(String::from),
        )
        .unwrap();
        assert!(run(&args).is_err());
        // CUSUM knobs without a CUSUM arm are flagged, not ignored.
        let args = Args::parse(
            "scenario --kind burst --phases 3 --completions 100 --warmup 10 \
             --cusum-h 9.0"
                .split_whitespace()
                .map(String::from),
        )
        .unwrap();
        assert!(run(&args).is_err());
    }

    #[test]
    fn scenario_sharded_resolve_and_compare_run() {
        // The sharded resolve mode drives a scenario end to end...
        let line = "scenario --kind phase_shift --policy grin --phases 3 \
                    --completions 150 --warmup 20 --resolve sharded --shards 2 \
                    --sync-every 60";
        let args = Args::parse(line.split_whitespace().map(String::from)).unwrap();
        run(&args).unwrap();
        // ...and --compare carries it as the fourth arm, with the
        // replicated A/B summary on top.
        let line = "scenario --kind slow_drift --policy grin --phases 3 \
                    --completions 120 --warmup 20 --n 8 --compare --reps 2";
        let args = Args::parse(line.split_whitespace().map(String::from)).unwrap();
        run(&args).unwrap();
    }

    #[test]
    fn scenario_priority_flags_gate_and_run() {
        // priority_mix + explicit priorities/deadlines runs end to end
        // under the adaptive resolve, reporting the class-0 line.
        let line = "scenario --kind priority_mix --mu 30,3.5;31,16 --policy grin \
                    --phases 2 --completions 150 --warmup 20 --resolve adaptive \
                    --priorities 4,1 --deadlines 1.0,0";
        let args = Args::parse(line.split_whitespace().map(String::from)).unwrap();
        run(&args).unwrap();
        // --priorities on a policy that cannot consume the weighted
        // solve is flagged as unknown, not silently ignored.
        let args = Args::parse(
            "scenario --kind burst --policy cab --phases 3 --completions 100 \
             --warmup 10 --resolve every_phase --priorities 4,1"
                .split_whitespace()
                .map(String::from),
        )
        .unwrap();
        let msg = run(&args).unwrap_err().to_string();
        assert!(msg.contains("unknown flag"), "{msg}");
        // Malformed values are parse errors.
        let args = Args::parse(
            "scenario --kind priority_mix --phases 2 --completions 50 --warmup 5 \
             --priorities 4,x"
                .split_whitespace()
                .map(String::from),
        )
        .unwrap();
        assert!(run(&args).unwrap_err().to_string().contains("bad priority"));
        // --compare under a non-GrIn policy has no priority arm, so
        // --priorities is flagged there too — never silently dropped.
        let args = Args::parse(
            "scenario --kind burst --policy cab --phases 3 --completions 100 \
             --warmup 10 --compare --reps 1 --priorities 4,1"
                .split_whitespace()
                .map(String::from),
        )
        .unwrap();
        let msg = run(&args).unwrap_err().to_string();
        assert!(msg.contains("unknown flag"), "{msg}");
        // --deadlines applies under any policy (pure accounting).
        let line = "scenario --kind burst --policy cab --phases 3 --completions 100 \
                    --warmup 10 --resolve every_phase --deadlines 5.0,0";
        let args = Args::parse(line.split_whitespace().map(String::from)).unwrap();
        run(&args).unwrap();
    }

    #[test]
    fn scenario_churn_flags_gate_and_run() {
        // The churn kind runs end to end with its shape knobs and a
        // re-dispatch budget cap.
        let line = "scenario --kind churn --policy grin --phases 3 \
                    --completions 150 --warmup 20 --resolve adaptive \
                    --churn 0.4 --churn-limp 0.5 --backup-budget 2";
        let args = Args::parse(line.split_whitespace().map(String::from)).unwrap();
        run(&args).unwrap();
        // An explicit fault plan rides on any kind.
        let line = "scenario --kind burst --policy grin --phases 3 \
                    --completions 150 --warmup 20 --resolve every_phase \
                    --fault-plan down:0@2;up:0@8";
        let args = Args::parse(line.split_whitespace().map(String::from)).unwrap();
        run(&args).unwrap();
        // --compare on churn reports the re-dispatch/downtime columns.
        let line = "scenario --kind churn --policy grin --phases 2 \
                    --completions 120 --warmup 20 --n 8 --compare --reps 2";
        let args = Args::parse(line.split_whitespace().map(String::from)).unwrap();
        run(&args).unwrap();
        // Churn-shape knobs without the churn kind are flagged, not
        // silently ignored.
        let args = Args::parse(
            "scenario --kind burst --phases 3 --completions 100 --warmup 10 \
             --resolve every_phase --churn 0.4"
                .split_whitespace()
                .map(String::from),
        )
        .unwrap();
        let msg = run(&args).unwrap_err().to_string();
        assert!(msg.contains("unknown flag"), "{msg}");
        // Malformed plans are rejected, as are events addressing
        // devices the fleet doesn't have.
        let args = Args::parse(
            "scenario --kind burst --phases 3 --completions 100 --warmup 10 \
             --fault-plan explode:0@5"
                .split_whitespace()
                .map(String::from),
        )
        .unwrap();
        assert!(run(&args).is_err());
        let args = Args::parse(
            "scenario --kind burst --phases 3 --completions 100 --warmup 10 \
             --fault-plan down:7@5"
                .split_whitespace()
                .map(String::from),
        )
        .unwrap();
        assert!(run(&args).is_err());
        // serve has no fault-injection path: --fault-plan is flagged
        // there, not silently ignored.
        let args = Args::parse(
            "serve --total 10 --fault-plan down:0@1"
                .split_whitespace()
                .map(String::from),
        )
        .unwrap();
        let msg = run(&args).unwrap_err().to_string();
        assert!(msg.contains("unknown flag"), "{msg}");
    }

    #[test]
    fn serve_flag_conflicts_are_rejected() {
        // --resolve-check is the single-leader cadence knob.
        let args = Args::parse(
            "serve --shards 2 --devices 4 --resolve-check 16"
                .split_whitespace()
                .map(String::from),
        )
        .unwrap();
        assert!(run(&args).is_err());
        // An explicit non-GrIn policy cannot drive the sharded plane.
        let args = Args::parse(
            "serve --shards 2 --devices 4 --policy cab --total 10"
                .split_whitespace()
                .map(String::from),
        )
        .unwrap();
        assert!(run(&args).is_err());
        // --trigger only applies to the adaptive/sharded estimation
        // loops: without either it is flagged, not silently ignored.
        let args = Args::parse(
            "serve --total 10 --trigger cusum"
                .split_whitespace()
                .map(String::from),
        )
        .unwrap();
        assert!(run(&args).is_err());
        // --priorities without a weighted-GrIn consumer (default policy
        // is CAB) is flagged as unknown, not silently ignored.
        let args = Args::parse(
            "serve --total 10 --priorities 4,1"
                .split_whitespace()
                .map(String::from),
        )
        .unwrap();
        let msg = run(&args).unwrap_err().to_string();
        assert!(msg.contains("unknown flag"), "{msg}");
        // On the GrIn policy it is consumed: the error here is the
        // total-0 validation, not an unknown flag.
        let args = Args::parse(
            "serve --policy grin --priorities 4,1 --deadlines 0.05,0.1 --total 0"
                .split_whitespace()
                .map(String::from),
        )
        .unwrap();
        let msg = run(&args).unwrap_err().to_string();
        assert!(!msg.contains("unknown flag"), "{msg}");
    }

    #[test]
    fn trigger_flags_gate_on_the_estimating_paths() {
        // serve: --trigger/--stale-after are consumed on the adaptive
        // path — the error here is the total-0 validation, not an
        // unknown flag.
        let args = Args::parse(
            "serve --adaptive --trigger cusum --stale-after 500 --total 0"
                .split_whitespace()
                .map(String::from),
        )
        .unwrap();
        let msg = run(&args).unwrap_err().to_string();
        assert!(!msg.contains("unknown flag"), "{msg}");
        // scenario: --trigger on a non-estimating resolve mode is
        // flagged, not silently ignored.
        let args = Args::parse(
            "scenario --kind burst --resolve static --trigger cusum"
                .split_whitespace()
                .map(String::from),
        )
        .unwrap();
        let msg = run(&args).unwrap_err().to_string();
        assert!(msg.contains("unknown flag"), "{msg}");
        // ...and so is --stale-after.
        let args = Args::parse(
            "scenario --kind burst --resolve every_phase --stale-after 10"
                .split_whitespace()
                .map(String::from),
        )
        .unwrap();
        let msg = run(&args).unwrap_err().to_string();
        assert!(msg.contains("unknown flag"), "{msg}");
    }

    #[test]
    fn objective_flags_gate_and_run_on_simulate_and_scenario() {
        // simulate: the full energy-knob set threads through under GrIn.
        let line = "simulate --policy grin --objective energy \
                    --power-scenario exponent:0.5 --power-coeff 2.0 \
                    --idle-power 0.5 --measure 300 --warmup 30";
        let args = Args::parse(line.split_whitespace().map(String::from)).unwrap();
        run(&args).unwrap();
        // A bad objective name is a parse error, not an unknown flag.
        let args = Args::parse(
            "simulate --policy grin --objective vibes"
                .split_whitespace()
                .map(String::from),
        )
        .unwrap();
        let msg = run(&args).unwrap_err().to_string();
        assert!(!msg.contains("unknown flag"), "{msg}");
        // scenario: the EDP objective drives an adaptive GrIn run.
        let line = "scenario --kind slow_drift --policy grin --phases 3 \
                    --completions 150 --warmup 20 --resolve adaptive \
                    --objective edp --power-scenario exponent:0.5";
        let args = Args::parse(line.split_whitespace().map(String::from)).unwrap();
        run(&args).unwrap();
        // scenario: --objective without a GrIn-backed solve is flagged
        // as unknown, not silently ignored.
        let args = Args::parse(
            "scenario --kind burst --policy cab --phases 3 --completions 100 \
             --warmup 10 --resolve every_phase --objective energy"
                .split_whitespace()
                .map(String::from),
        )
        .unwrap();
        let msg = run(&args).unwrap_err().to_string();
        assert!(msg.contains("unknown flag"), "{msg}");
    }

    #[test]
    fn scenario_compare_runs_the_energy_arm_under_grin() {
        // --compare under GrIn adds the energy-objective arm; an
        // explicit --objective picks its axis.
        let line = "scenario --kind slow_drift --policy grin --phases 3 \
                    --completions 120 --warmup 20 --n 8 --compare --reps 2 \
                    --objective energy --power-scenario exponent:0.5";
        let args = Args::parse(line.split_whitespace().map(String::from)).unwrap();
        run(&args).unwrap();
        // Under a non-GrIn policy there is no energy arm, so the
        // objective knobs are flagged.
        let args = Args::parse(
            "scenario --kind burst --policy cab --phases 3 --completions 100 \
             --warmup 10 --compare --reps 1 --power-coeff 2.0"
                .split_whitespace()
                .map(String::from),
        )
        .unwrap();
        let msg = run(&args).unwrap_err().to_string();
        assert!(msg.contains("unknown flag"), "{msg}");
    }

    #[test]
    fn serve_objective_flags_gate_on_the_grin_backed_paths() {
        // Default policy is CAB: the objective knobs are flagged.
        let args = Args::parse(
            "serve --total 10 --objective energy"
                .split_whitespace()
                .map(String::from),
        )
        .unwrap();
        let msg = run(&args).unwrap_err().to_string();
        assert!(msg.contains("unknown flag"), "{msg}");
        // On GrIn they are consumed: the error here is the total-0
        // validation, not an unknown flag.
        let args = Args::parse(
            "serve --policy grin --objective edp --power-scenario constant \
             --power-coeff 2.0 --total 0"
                .split_whitespace()
                .map(String::from),
        )
        .unwrap();
        let msg = run(&args).unwrap_err().to_string();
        assert!(!msg.contains("unknown flag"), "{msg}");
    }

    #[test]
    fn sweep_json_snapshot_is_thread_count_invariant() {
        let dir = std::env::temp_dir();
        // Pid-suffixed so concurrent test processes don't race on the files.
        let pid = std::process::id();
        let p1 = dir.join(format!("hetsched_sweep_t1_{pid}.json"));
        let p4 = dir.join(format!("hetsched_sweep_t4_{pid}.json"));
        for (threads, path) in [(1, &p1), (4, &p4)] {
            let line = format!(
                "sweep --quick --reps 2 --measure 200 --warmup 20 \
                 --threads {threads} --json {}",
                path.display()
            );
            let args = Args::parse(line.split_whitespace().map(String::from)).unwrap();
            run(&args).unwrap();
        }
        let a = std::fs::read_to_string(&p1).unwrap();
        let b = std::fs::read_to_string(&p4).unwrap();
        // The snapshot embeds per-cell f64 bit patterns and omits the
        // thread count, so the CI determinism gate can compare files
        // byte for byte.
        assert_eq!(a, b, "sweep snapshot depends on thread count");
        let doc = crate::config::json::Json::parse(&a).unwrap();
        assert_eq!(doc.req("cells").unwrap().as_arr().unwrap().len(), 15);
        let _ = std::fs::remove_file(&p1);
        let _ = std::fs::remove_file(&p4);
    }

    #[test]
    fn sweep_command_runs_replicated_quick_grid() {
        let args = Args::parse(
            "sweep --quick --reps 2 --measure 200 --warmup 20 --threads 2"
                .split_whitespace()
                .map(String::from),
        )
        .unwrap();
        run(&args).unwrap();
        // Bad policy list is rejected.
        let args = Args::parse(
            "sweep --policies cab,fifo".split_whitespace().map(String::from),
        )
        .unwrap();
        assert!(run(&args).is_err());
    }

    #[test]
    fn solve_and_classify_run() {
        let args = Args::parse(
            "solve --mu 20,15;3,8 --populations 6,6 --solver grin"
                .split_whitespace()
                .map(String::from),
        )
        .unwrap();
        run(&args).unwrap();
        let args = Args::parse(
            "classify --mu 20,15;3,8".split_whitespace().map(String::from),
        )
        .unwrap();
        run(&args).unwrap();
    }
}
