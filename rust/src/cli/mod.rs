//! Command-line substrate (no `clap` available offline).
//!
//! [`parser`] implements a small, typed argument parser: positional
//! subcommands, `--flag value`, `--flag=value`, boolean switches, typed
//! getters with defaults and "unknown flag" diagnostics.  [`commands`]
//! wires the `hetsched` launcher subcommands onto the library.

pub mod commands;
pub mod parser;

pub use parser::Args;
