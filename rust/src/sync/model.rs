//! In-repo loom-style DFS model checker (compiled only with
//! `--features model`).
//!
//! [`Checker::run`] executes a closure repeatedly, once per distinct
//! bounded interleaving of its model threads, and reports the first
//! schedule whose assertions fail (or that deadlocks).  The design is
//! token-passing: model threads are real OS threads, but exactly one
//! holds the *token* at any instant, and every instrumented operation
//! (atomic access, mutex lock/unlock, condvar wait/notify, spawn,
//! join) is a scheduling point where the token may move.  The explorer
//! enumerates schedules depth-first over the recorded choice points —
//! re-running the closure with a longer forced prefix each time — with
//! two bounds to keep the state space finite: a step cap
//! ([`Checker::max_steps`]) and a preemption bound
//! ([`Checker::preemption_bound`], the classic CHESS-style bound: only
//! so many involuntary context switches per execution).
//!
//! What the model covers, and what it does not:
//!
//! * **Covered:** all sequentially consistent interleavings of
//!   instrumented operations within the bounds, mutex blocking,
//!   condvar wait/notify (no spurious wakeups; `notify_one` wakes the
//!   lowest-tid waiter), deadlock detection, and `wait_timeout`
//!   modeled as *timeout-fires-only-at-quiescence*: a timed wait wakes
//!   with `timed_out() == true` exactly when no other thread can run,
//!   which keeps exploration bounded while still exercising both the
//!   notified and timed-out paths.
//! * **Not covered:** weak-memory reorderings (every access is
//!   executed under the serializing token, so `Relaxed` behaves like
//!   `SeqCst` here).  The relaxed-memory axis is delegated to the Miri
//!   and ThreadSanitizer CI jobs — see `.github/workflows/sanitizers.yml`.
//!
//! Closures under test must be deterministic given the schedule
//! (no wall-clock time, no OS randomness) and must create the shared
//! state they exercise *inside* the closure, so each execution starts
//! fresh.  Spawn model threads with [`spawn`]; everything they touch
//! concurrently must go through the instrumented types below, which
//! fall through to plain `std` behavior when used outside a
//! [`Checker::run`] (so the ordinary test suite still passes when the
//! crate is compiled with the feature enabled).

// srclint: allow-file(index-reachable) — model-checker state vectors are indexed by thread ids it allocated

use std::any::Any;
use std::cell::RefCell;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{
    AtomicU64 as StdAtomicU64, AtomicUsize as StdAtomicUsize, Ordering as AtomOrd,
};
use std::sync::{
    Arc, Condvar as StdCondvar, LockResult, Mutex as StdMutex, MutexGuard as StdMutexGuard,
    PoisonError,
};
use std::time::Duration;

pub use std::sync::atomic::Ordering;

// ---------------------------------------------------------------------------
// Scheduler core
// ---------------------------------------------------------------------------

/// Panic payload used to unwind secondary threads once an execution has
/// already failed; the thread wrapper recognizes it and does not record
/// it as a violation of its own.
struct Abort;

#[derive(Clone, Debug, PartialEq)]
enum TState {
    Runnable,
    BlockedMutex(usize),
    BlockedCondvar { cv: usize, timed: bool },
    BlockedJoin(usize),
    Finished,
}

#[derive(Clone, Debug)]
struct Slot {
    state: TState,
    /// Set when a `BlockedCondvar { timed: true }` thread was woken by
    /// the quiescence rule rather than a notify.
    timed_out: bool,
}

impl Slot {
    fn runnable() -> Self {
        Slot { state: TState::Runnable, timed_out: false }
    }
}

struct State {
    threads: Vec<Slot>,
    /// Thread id currently holding the token.
    current: usize,
    /// Forced choice indices for this execution (DFS replay prefix).
    prefix: Vec<usize>,
    /// Recorded `(num_options, chosen_index)` per multi-option choice.
    trace: Vec<(usize, usize)>,
    /// Number of multi-option decisions taken so far.
    decisions: usize,
    steps: usize,
    max_steps: usize,
    preemptions: usize,
    preemption_bound: usize,
    mutex_owner: Vec<Option<usize>>,
    condvars: usize,
    failure: Option<String>,
}

struct Sched {
    /// Execution generation, used to invalidate mutex/condvar ids that
    /// leak across executions via captured state.
    gen: u32,
    m: StdMutex<State>,
    cv: StdCondvar,
    handles: StdMutex<Vec<std::thread::JoinHandle<()>>>,
}

static EXEC_GEN: StdAtomicU64 = StdAtomicU64::new(1);

#[derive(Clone)]
struct Ctx {
    sched: Arc<Sched>,
    tid: usize,
}

thread_local! {
    static CTX: RefCell<Option<Ctx>> = const { RefCell::new(None) };
}

fn current_ctx() -> Option<Ctx> {
    CTX.with(|c| c.borrow().clone())
}

/// Scheduling point for an instrumented operation performed outside any
/// model run: a no-op.
fn sched_op() {
    if let Some(ctx) = current_ctx() {
        ctx.sched.yield_now(ctx.tid);
    }
}

impl Sched {
    fn locked(&self) -> std::sync::MutexGuard<'_, State> {
        match self.m.lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        }
    }

    /// Pick the next thread to hold the token.  Called with the state
    /// lock held; `yielder` has already updated its own slot.
    fn reschedule(&self, s: &mut State, yielder: usize) {
        if s.failure.is_some() {
            self.cv.notify_all();
            return;
        }
        s.steps += 1;
        if s.steps > s.max_steps {
            s.failure = Some(format!(
                "model: exceeded max_steps ({}) — unbounded loop, or raise Checker.max_steps",
                s.max_steps
            ));
            self.cv.notify_all();
            return;
        }
        let mut runnable: Vec<usize> = (0..s.threads.len())
            .filter(|&t| s.threads[t].state == TState::Runnable)
            .collect();
        if runnable.is_empty() {
            // Quiescence: fire every pending wait_timeout at once.
            let timed: Vec<usize> = (0..s.threads.len())
                .filter(|&t| {
                    matches!(s.threads[t].state, TState::BlockedCondvar { timed: true, .. })
                })
                .collect();
            if !timed.is_empty() {
                for &t in &timed {
                    s.threads[t].state = TState::Runnable;
                    s.threads[t].timed_out = true;
                }
                runnable = timed;
            } else if s.threads.iter().all(|t| t.state == TState::Finished) {
                self.cv.notify_all();
                return;
            } else {
                s.failure = Some(format!(
                    "model: deadlock — no runnable threads, states {:?}",
                    s.threads.iter().map(|t| t.state.clone()).collect::<Vec<_>>()
                ));
                self.cv.notify_all();
                return;
            }
        }
        // Options ordered: the yielding thread first (continuing without a
        // context switch), then the rest by tid — so execution 0 of every
        // DFS is the fully sequential schedule.
        let yielder_runnable = runnable.contains(&yielder);
        let mut options: Vec<usize> = Vec::with_capacity(runnable.len());
        if yielder_runnable {
            options.push(yielder);
        }
        options.extend(runnable.iter().copied().filter(|&t| t != yielder));
        if yielder_runnable && s.preemptions >= s.preemption_bound {
            options.truncate(1);
        }
        let chosen = if options.len() == 1 {
            options[0]
        } else {
            let idx = if s.decisions < s.prefix.len() { s.prefix[s.decisions] } else { 0 };
            s.decisions += 1;
            s.trace.push((options.len(), idx));
            options[idx]
        };
        if yielder_runnable && chosen != yielder {
            s.preemptions += 1;
        }
        s.current = chosen;
        self.cv.notify_all();
    }

    /// Voluntary scheduling point: the calling thread stays runnable and
    /// may or may not keep the token.
    fn yield_now(&self, tid: usize) {
        let mut s = self.locked();
        if s.failure.is_some() {
            drop(s);
            std::panic::panic_any(Abort);
        }
        self.reschedule(&mut s, tid);
        while s.current != tid {
            if s.failure.is_some() {
                drop(s);
                std::panic::panic_any(Abort);
            }
            s = match self.cv.wait(s) {
                Ok(g) => g,
                Err(p) => p.into_inner(),
            };
        }
        if s.failure.is_some() {
            drop(s);
            std::panic::panic_any(Abort);
        }
    }

    /// Block the calling thread in `state` until it is made runnable
    /// again *and* scheduled.  Returns the slot's `timed_out` flag.
    fn block(&self, tid: usize, state: TState) -> bool {
        let mut s = self.locked();
        if s.failure.is_some() {
            drop(s);
            std::panic::panic_any(Abort);
        }
        s.threads[tid].state = state;
        s.threads[tid].timed_out = false;
        self.reschedule(&mut s, tid);
        while s.current != tid || s.threads[tid].state != TState::Runnable {
            if s.failure.is_some() {
                drop(s);
                std::panic::panic_any(Abort);
            }
            s = match self.cv.wait(s) {
                Ok(g) => g,
                Err(p) => p.into_inner(),
            };
        }
        let timed_out = s.threads[tid].timed_out;
        s.threads[tid].timed_out = false;
        timed_out
    }

    fn register_mutex(&self) -> usize {
        let mut s = self.locked();
        s.mutex_owner.push(None);
        s.mutex_owner.len() - 1
    }

    fn register_condvar(&self) -> usize {
        let mut s = self.locked();
        let id = s.condvars;
        s.condvars += 1;
        id
    }

    fn mutex_lock(&self, tid: usize, mid: usize) {
        self.yield_now(tid);
        loop {
            {
                let mut s = self.locked();
                if s.failure.is_some() {
                    drop(s);
                    std::panic::panic_any(Abort);
                }
                if s.mutex_owner[mid].is_none() {
                    s.mutex_owner[mid] = Some(tid);
                    return;
                }
            }
            self.block(tid, TState::BlockedMutex(mid));
        }
    }

    fn mutex_unlock(&self, tid: usize, mid: usize) {
        // Never panic out of a Drop that runs during unwinding.
        if !std::thread::panicking() {
            self.yield_now(tid);
        }
        let mut s = self.locked();
        s.mutex_owner[mid] = None;
        for t in 0..s.threads.len() {
            if s.threads[t].state == TState::BlockedMutex(mid) {
                s.threads[t].state = TState::Runnable;
            }
        }
    }

    /// Atomically release `mid`, wait on condvar `cvid`, then
    /// re-acquire `mid`.  Returns true if woken by the quiescence
    /// timeout rule rather than a notify.
    fn condvar_wait(&self, tid: usize, cvid: usize, mid: usize, timed: bool) -> bool {
        self.yield_now(tid);
        {
            let mut s = self.locked();
            s.mutex_owner[mid] = None;
            for t in 0..s.threads.len() {
                if s.threads[t].state == TState::BlockedMutex(mid) {
                    s.threads[t].state = TState::Runnable;
                }
            }
        }
        let timed_out = self.block(tid, TState::BlockedCondvar { cv: cvid, timed });
        // Re-acquire the mutex; we hold the token coming out of block().
        loop {
            {
                let mut s = self.locked();
                if s.failure.is_some() {
                    drop(s);
                    std::panic::panic_any(Abort);
                }
                if s.mutex_owner[mid].is_none() {
                    s.mutex_owner[mid] = Some(tid);
                    return timed_out;
                }
            }
            self.block(tid, TState::BlockedMutex(mid));
        }
    }

    fn condvar_notify(&self, tid: usize, cvid: usize, all: bool) {
        self.yield_now(tid);
        let mut s = self.locked();
        for t in 0..s.threads.len() {
            if matches!(s.threads[t].state, TState::BlockedCondvar { cv, .. } if cv == cvid) {
                s.threads[t].state = TState::Runnable;
                s.threads[t].timed_out = false;
                if !all {
                    break;
                }
            }
        }
    }

    fn join_wait(&self, tid: usize, target: usize) {
        self.yield_now(tid);
        loop {
            {
                let s = self.locked();
                if s.failure.is_some() {
                    drop(s);
                    std::panic::panic_any(Abort);
                }
                if s.threads[target].state == TState::Finished {
                    return;
                }
            }
            self.block(tid, TState::BlockedJoin(target));
        }
    }

    fn wait_first_turn(&self, tid: usize) -> bool {
        let mut s = self.locked();
        loop {
            if s.failure.is_some() {
                return false;
            }
            if s.current == tid {
                return true;
            }
            s = match self.cv.wait(s) {
                Ok(g) => g,
                Err(p) => p.into_inner(),
            };
        }
    }

    fn thread_finish(&self, tid: usize, panic_msg: Option<String>) {
        let mut s = self.locked();
        s.threads[tid].state = TState::Finished;
        for t in 0..s.threads.len() {
            if s.threads[t].state == TState::BlockedJoin(tid) {
                s.threads[t].state = TState::Runnable;
            }
        }
        if let Some(msg) = panic_msg {
            if s.failure.is_none() {
                s.failure = Some(msg);
            }
        }
        if s.failure.is_none() {
            self.reschedule(&mut s, tid);
        }
        self.cv.notify_all();
    }
}

fn payload_to_string(p: Box<dyn Any + Send>) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "model thread panicked (non-string payload)".to_string()
    }
}

fn launch(sched: Arc<Sched>, tid: usize, body: Box<dyn FnOnce() + Send>) -> std::thread::JoinHandle<()> {
    std::thread::spawn(move || {
        CTX.with(|c| *c.borrow_mut() = Some(Ctx { sched: sched.clone(), tid }));
        let panic_msg = if sched.wait_first_turn(tid) {
            match catch_unwind(AssertUnwindSafe(body)) {
                Ok(()) => None,
                Err(p) if p.is::<Abort>() => None,
                Err(p) => Some(payload_to_string(p)),
            }
        } else {
            None
        };
        sched.thread_finish(tid, panic_msg);
        CTX.with(|c| *c.borrow_mut() = None);
    })
}

// ---------------------------------------------------------------------------
// Public checker API
// ---------------------------------------------------------------------------

/// One schedule that violated an assertion (or deadlocked).
#[derive(Clone, Debug)]
pub struct Violation {
    /// Panic/deadlock message from the failing execution.
    pub message: String,
    /// Choice indices (one per multi-option scheduling decision) that
    /// reproduce the failing schedule.
    pub schedule: Vec<usize>,
}

/// Outcome of a [`Checker::run`].
#[derive(Clone, Debug)]
pub struct Report {
    /// Number of distinct executions explored.
    pub executions: usize,
    /// First failing schedule, if any.
    pub violation: Option<Violation>,
    /// True iff the bounded schedule space was fully enumerated
    /// (no violation, and `max_executions` was not hit).
    pub complete: bool,
}

/// Bounded DFS explorer over thread interleavings.
#[derive(Clone, Debug)]
pub struct Checker {
    /// Cap on scheduling points per execution; exceeding it is reported
    /// as a violation (it means a loop the bounds cannot terminate).
    pub max_steps: usize,
    /// CHESS-style preemption bound: maximum involuntary context
    /// switches per execution.
    pub preemption_bound: usize,
    /// Safety cap on the number of executions.
    pub max_executions: usize,
}

impl Default for Checker {
    fn default() -> Self {
        Checker { max_steps: 5_000, preemption_bound: 2, max_executions: 200_000 }
    }
}

impl Checker {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn preemption_bound(mut self, b: usize) -> Self {
        self.preemption_bound = b;
        self
    }

    pub fn max_executions(mut self, n: usize) -> Self {
        self.max_executions = n;
        self
    }

    /// Explore every bounded interleaving of `f`.  Returns rather than
    /// panics, so negative tests can assert that a violation *is* found.
    pub fn run<F>(&self, f: F) -> Report
    where
        F: Fn() + Send + Sync + 'static,
    {
        let f: Arc<dyn Fn() + Send + Sync> = Arc::new(f);
        let mut prefix: Vec<usize> = Vec::new();
        let mut executions = 0usize;
        loop {
            executions += 1;
            let (failure, trace) = self.run_once(prefix.clone(), Arc::clone(&f));
            if let Some(message) = failure {
                let schedule = trace.iter().map(|t| t.1).collect();
                return Report { executions, violation: Some(Violation { message, schedule }), complete: false };
            }
            // DFS: advance the deepest choice that still has options left.
            let mut next: Option<Vec<usize>> = None;
            for i in (0..trace.len()).rev() {
                let (n, c) = trace[i];
                if c + 1 < n {
                    let mut p: Vec<usize> = trace[..i].iter().map(|t| t.1).collect();
                    p.push(c + 1);
                    next = Some(p);
                    break;
                }
            }
            match next {
                Some(p) if executions < self.max_executions => prefix = p,
                Some(_) => return Report { executions, violation: None, complete: false },
                None => return Report { executions, violation: None, complete: true },
            }
        }
    }

    fn run_once(
        &self,
        prefix: Vec<usize>,
        f: Arc<dyn Fn() + Send + Sync>,
    ) -> (Option<String>, Vec<(usize, usize)>) {
        // ordering: generation counter only needs uniqueness, not ordering.
        // srclint: allow(as-truncation) — the value is masked to 32 bits on the same line
        let gen = (EXEC_GEN.fetch_add(1, AtomOrd::Relaxed) & 0xffff_ffff) as u32;
        let sched = Arc::new(Sched {
            gen,
            m: StdMutex::new(State {
                threads: vec![Slot::runnable()],
                current: 0,
                prefix,
                trace: Vec::new(),
                decisions: 0,
                steps: 0,
                max_steps: self.max_steps,
                preemptions: 0,
                preemption_bound: self.preemption_bound,
                mutex_owner: Vec::new(),
                condvars: 0,
                failure: None,
            }),
            cv: StdCondvar::new(),
            handles: StdMutex::new(Vec::new()),
        });
        let root = launch(Arc::clone(&sched), 0, Box::new(move || f()));
        {
            let mut h = match sched.handles.lock() {
                Ok(g) => g,
                Err(p) => p.into_inner(),
            };
            h.push(root);
        }
        // Wait for every model thread (root + spawned) to finish.
        {
            let mut s = sched.locked();
            while !s.threads.iter().all(|t| t.state == TState::Finished) {
                s = match sched.cv.wait(s) {
                    Ok(g) => g,
                    Err(p) => p.into_inner(),
                };
            }
        }
        loop {
            let h = {
                let mut hs = match sched.handles.lock() {
                    Ok(g) => g,
                    Err(p) => p.into_inner(),
                };
                hs.pop()
            };
            match h {
                Some(h) => {
                    // srclint: allow(discarded-result) — a panicked schedule thread already recorded its violation; join's Err adds nothing
                    let _ = h.join();
                }
                None => break,
            }
        }
        let s = sched.locked();
        (s.failure.clone(), s.trace.clone())
    }
}

/// Convenience wrapper: run `f` under a default [`Checker`] and panic
/// with the failing schedule if a violation is found.
pub fn check<F>(f: F)
where
    F: Fn() + Send + Sync + 'static,
{
    let report = Checker::default().run(f);
    if let Some(v) = report.violation {
        // srclint: allow(panic-reachable) — aborting with the violation trace is the checker's reporting mechanism
        panic!(
            "model check failed after {} executions\n  schedule: {:?}\n  {}",
            report.executions, v.schedule, v.message
        );
    }
    assert!(report.complete, "model check hit max_executions without completing");
}

/// Spawn a model thread.  Must be called from inside a [`Checker::run`]
/// closure (or another model thread).
pub fn spawn<F, T>(f: F) -> JoinHandle<T>
where
    F: FnOnce() -> T + Send + 'static,
    T: Send + 'static,
{
    // srclint: allow(panic-reachable) — model::spawn outside Checker::run is a test-harness misuse worth a loud stop
    let ctx = current_ctx().expect("model::spawn called outside a Checker::run");
    let sched = ctx.sched;
    let tid = {
        let mut s = sched.locked();
        s.threads.push(Slot::runnable());
        s.threads.len() - 1
    };
    let slot: Arc<StdMutex<Option<T>>> = Arc::new(StdMutex::new(None));
    let slot2 = Arc::clone(&slot);
    let h = launch(
        Arc::clone(&sched),
        tid,
        Box::new(move || {
            let r = f();
            let mut g = match slot2.lock() {
                Ok(g) => g,
                Err(p) => p.into_inner(),
            };
            *g = Some(r);
        }),
    );
    {
        let mut hs = match sched.handles.lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        };
        hs.push(h);
    }
    // Spawning makes a new thread schedulable: that is an observable
    // scheduling point.
    sched.yield_now(ctx.tid);
    JoinHandle { slot, target: tid }
}

/// Handle to a model thread; mirrors `std::thread::JoinHandle`.
pub struct JoinHandle<T> {
    slot: Arc<StdMutex<Option<T>>>,
    target: usize,
}

impl<T> JoinHandle<T> {
    pub fn join(self) -> std::thread::Result<T> {
        // srclint: allow(panic-reachable) — join outside Checker::run is a test-harness misuse worth a loud stop
        let ctx = current_ctx().expect("JoinHandle::join called outside a Checker::run");
        ctx.sched.join_wait(ctx.tid, self.target);
        let mut g = match self.slot.lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        };
        match g.take() {
            Some(v) => Ok(v),
            None => Err(Box::new("model thread panicked".to_string()) as Box<dyn Any + Send>),
        }
    }
}

// ---------------------------------------------------------------------------
// Instrumented primitives
// ---------------------------------------------------------------------------

macro_rules! instrumented_atomic {
    ($name:ident, $std:path, $prim:ty) => {
        /// Instrumented atomic: every operation is a model scheduling
        /// point; outside a model run it is the plain `std` op.
        #[derive(Debug, Default)]
        pub struct $name($std);

        impl $name {
            pub const fn new(v: $prim) -> Self {
                Self(<$std>::new(v))
            }
            pub fn load(&self, o: Ordering) -> $prim {
                sched_op();
                self.0.load(o)
            }
            pub fn store(&self, v: $prim, o: Ordering) {
                sched_op();
                self.0.store(v, o)
            }
            pub fn swap(&self, v: $prim, o: Ordering) -> $prim {
                sched_op();
                self.0.swap(v, o)
            }
            pub fn fetch_add(&self, v: $prim, o: Ordering) -> $prim {
                sched_op();
                self.0.fetch_add(v, o)
            }
            pub fn fetch_sub(&self, v: $prim, o: Ordering) -> $prim {
                sched_op();
                self.0.fetch_sub(v, o)
            }
            pub fn compare_exchange(
                &self,
                current: $prim,
                new: $prim,
                success: Ordering,
                failure: Ordering,
            ) -> Result<$prim, $prim> {
                sched_op();
                self.0.compare_exchange(current, new, success, failure)
            }
            /// Under the serializing token a weak CAS cannot fail
            /// spuriously, so this is the strong variant.
            pub fn compare_exchange_weak(
                &self,
                current: $prim,
                new: $prim,
                success: Ordering,
                failure: Ordering,
            ) -> Result<$prim, $prim> {
                self.compare_exchange(current, new, success, failure)
            }
            pub fn get_mut(&mut self) -> &mut $prim {
                self.0.get_mut()
            }
            pub fn into_inner(self) -> $prim {
                self.0.into_inner()
            }
        }
    };
}

instrumented_atomic!(AtomicU64, std::sync::atomic::AtomicU64, u64);
instrumented_atomic!(AtomicI64, std::sync::atomic::AtomicI64, i64);
instrumented_atomic!(AtomicUsize, std::sync::atomic::AtomicUsize, usize);

/// Instrumented `AtomicBool` (no fetch_add/fetch_sub).
#[derive(Debug, Default)]
pub struct AtomicBool(std::sync::atomic::AtomicBool);

impl AtomicBool {
    pub const fn new(v: bool) -> Self {
        Self(std::sync::atomic::AtomicBool::new(v))
    }
    pub fn load(&self, o: Ordering) -> bool {
        sched_op();
        self.0.load(o)
    }
    pub fn store(&self, v: bool, o: Ordering) {
        sched_op();
        self.0.store(v, o)
    }
    pub fn swap(&self, v: bool, o: Ordering) -> bool {
        sched_op();
        self.0.swap(v, o)
    }
}

/// Packed `(generation << 32) | id` lazy registration for mutexes and
/// condvars; `u64::MAX` means "not yet registered in any execution".
fn model_id(cell: &StdAtomicU64, ctx: &Ctx, register: impl FnOnce(&Sched) -> usize) -> usize {
    // ordering: id cell is only touched by the token-holding thread,
    // so Relaxed is already serialized.
    let packed = cell.load(AtomOrd::Relaxed);
    // srclint: allow(as-truncation) — upper-half extraction of a packed 32/32 word
    if packed != u64::MAX && (packed >> 32) as u32 == ctx.sched.gen {
        return (packed & 0xffff_ffff) as usize;
    }
    let id = register(&ctx.sched);
    cell.store(((ctx.sched.gen as u64) << 32) | id as u64, AtomOrd::Relaxed);
    id
}

/// Instrumented mutex.  Model-level blocking is arbitrated by the
/// scheduler; the inner `std` mutex only carries the data (it is never
/// contended during a model run because the token serializes access).
pub struct Mutex<T: ?Sized> {
    id: StdAtomicU64,
    inner: StdMutex<T>,
}

impl<T> Mutex<T> {
    pub fn new(t: T) -> Self {
        Mutex { id: StdAtomicU64::new(u64::MAX), inner: StdMutex::new(t) }
    }

    pub fn lock(&self) -> LockResult<MutexGuard<'_, T>> {
        match current_ctx() {
            None => match self.inner.lock() {
                Ok(real) => Ok(MutexGuard { lock: self, real: Some(real), model: None }),
                Err(p) => Err(PoisonError::new(MutexGuard {
                    lock: self,
                    real: Some(p.into_inner()),
                    model: None,
                })),
            },
            Some(ctx) => {
                let mid = model_id(&self.id, &ctx, |s| s.register_mutex());
                ctx.sched.mutex_lock(ctx.tid, mid);
                let model = Some((Arc::clone(&ctx.sched), ctx.tid, mid));
                match self.inner.lock() {
                    Ok(real) => Ok(MutexGuard { lock: self, real: Some(real), model }),
                    Err(p) => Err(PoisonError::new(MutexGuard {
                        lock: self,
                        real: Some(p.into_inner()),
                        model,
                    })),
                }
            }
        }
    }

    pub fn into_inner(self) -> LockResult<T> {
        self.inner.into_inner()
    }

    pub fn get_mut(&mut self) -> LockResult<&mut T> {
        self.inner.get_mut()
    }
}

impl<T: std::fmt::Debug> std::fmt::Debug for Mutex<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        self.inner.fmt(f)
    }
}

/// Guard for the instrumented [`Mutex`]; releases the model-level lock
/// on drop (after the real guard).
pub struct MutexGuard<'a, T: ?Sized> {
    lock: &'a Mutex<T>,
    real: Option<StdMutexGuard<'a, T>>,
    model: Option<(Arc<Sched>, usize, usize)>,
}

impl<T: ?Sized> std::ops::Deref for MutexGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        // srclint: allow(panic-reachable) — guards are disarmed only on drop, so deref during life always has the value
        self.real.as_deref().expect("model MutexGuard used after disarm")
    }
}

impl<T: ?Sized> std::ops::DerefMut for MutexGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        // srclint: allow(panic-reachable) — guards are disarmed only on drop, so deref during life always has the value
        self.real.as_deref_mut().expect("model MutexGuard used after disarm")
    }
}

impl<T: ?Sized> Drop for MutexGuard<'_, T> {
    fn drop(&mut self) {
        drop(self.real.take());
        if let Some((sched, tid, mid)) = self.model.take() {
            sched.mutex_unlock(tid, mid);
        }
    }
}

/// Result of an instrumented `wait_timeout`; mirrors
/// `std::sync::WaitTimeoutResult`.
#[derive(Clone, Copy, Debug)]
pub struct WaitTimeoutResult {
    timed: bool,
}

impl WaitTimeoutResult {
    pub fn timed_out(&self) -> bool {
        self.timed
    }
}

/// Instrumented condvar.  In a model run, `wait_timeout` times out only
/// at quiescence (when nothing else can run); outside a run it is the
/// real condvar.
pub struct Condvar {
    id: StdAtomicU64,
    real: StdCondvar,
}

impl Default for Condvar {
    fn default() -> Self {
        Self::new()
    }
}

impl Condvar {
    pub fn new() -> Self {
        Condvar { id: StdAtomicU64::new(u64::MAX), real: StdCondvar::new() }
    }

    pub fn notify_one(&self) {
        match current_ctx() {
            None => self.real.notify_one(),
            Some(ctx) => {
                let cvid = model_id(&self.id, &ctx, |s| s.register_condvar());
                ctx.sched.condvar_notify(ctx.tid, cvid, false);
            }
        }
    }

    pub fn notify_all(&self) {
        match current_ctx() {
            None => self.real.notify_all(),
            Some(ctx) => {
                let cvid = model_id(&self.id, &ctx, |s| s.register_condvar());
                ctx.sched.condvar_notify(ctx.tid, cvid, true);
            }
        }
    }

    pub fn wait<'a, T>(&self, guard: MutexGuard<'a, T>) -> LockResult<MutexGuard<'a, T>> {
        self.wait_inner(guard, None).map(|(g, _)| g).map_err(|p| {
            let (g, _) = p.into_inner();
            PoisonError::new(g)
        })
    }

    pub fn wait_timeout<'a, T>(
        &self,
        guard: MutexGuard<'a, T>,
        dur: Duration,
    ) -> LockResult<(MutexGuard<'a, T>, WaitTimeoutResult)> {
        self.wait_inner(guard, Some(dur))
    }

    fn wait_inner<'a, T>(
        &self,
        mut guard: MutexGuard<'a, T>,
        dur: Option<Duration>,
    ) -> LockResult<(MutexGuard<'a, T>, WaitTimeoutResult)> {
        match current_ctx() {
            None => {
                // srclint: allow(panic-reachable) — the guard is live here: disarm happens exactly once, below this take
                let real = guard.real.take().expect("model MutexGuard used after disarm");
                match dur {
                    Some(d) => match self.real.wait_timeout(real, d) {
                        Ok((g, w)) => {
                            guard.real = Some(g);
                            Ok((guard, WaitTimeoutResult { timed: w.timed_out() }))
                        }
                        Err(p) => {
                            let (g, w) = p.into_inner();
                            guard.real = Some(g);
                            Err(PoisonError::new((guard, WaitTimeoutResult {
                                timed: w.timed_out(),
                            })))
                        }
                    },
                    None => match self.real.wait(real) {
                        Ok(g) => {
                            guard.real = Some(g);
                            Ok((guard, WaitTimeoutResult { timed: false }))
                        }
                        Err(p) => {
                            guard.real = Some(p.into_inner());
                            Err(PoisonError::new((guard, WaitTimeoutResult { timed: false })))
                        }
                    },
                }
            }
            Some(ctx) => {
                let cvid = model_id(&self.id, &ctx, |s| s.register_condvar());
                let lock = guard.lock;
                // srclint: allow(panic-reachable) — the guard is live here: disarm happens exactly once, below this take
                let (_, tid, mid) = guard.model.take().expect(
                    "model Condvar::wait on a guard locked outside the model run",
                );
                // Drop the real guard (scheduler owns exclusion from here).
                drop(guard.real.take());
                drop(guard);
                let timed = ctx.sched.condvar_wait(tid, cvid, mid, dur.is_some());
                let model = Some((Arc::clone(&ctx.sched), tid, mid));
                let rebuilt = match lock.inner.lock() {
                    Ok(real) => MutexGuard { lock, real: Some(real), model },
                    Err(p) => MutexGuard { lock, real: Some(p.into_inner()), model },
                };
                Ok((rebuilt, WaitTimeoutResult { timed }))
            }
        }
    }
}

impl std::fmt::Debug for Condvar {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("Condvar")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Satellite gate (seeded-bug detection): a torn load-then-store
    /// increment loses an update under some interleaving, and the
    /// explorer must find it.
    #[test]
    fn finds_torn_counter_bug() {
        let report = Checker::default().run(|| {
            let c = Arc::new(AtomicU64::new(0));
            let mk = |c: Arc<AtomicU64>| {
                spawn(move || {
                    // Deliberately torn read-modify-write.
                    let v = c.load(Ordering::SeqCst);
                    c.store(v + 1, Ordering::SeqCst);
                })
            };
            let a = mk(Arc::clone(&c));
            let b = mk(Arc::clone(&c));
            a.join().unwrap();
            b.join().unwrap();
            assert_eq!(c.load(Ordering::SeqCst), 2, "lost update");
        });
        let v = report.violation.expect("explorer must find the torn counter");
        assert!(v.message.contains("lost update"), "unexpected violation: {}", v.message);
    }

    /// The same counter with a real atomic RMW has no lost update in
    /// any schedule, and the bounded space is fully enumerated.
    #[test]
    fn atomic_counter_is_clean() {
        let report = Checker::default().run(|| {
            let c = Arc::new(AtomicU64::new(0));
            let mk = |c: Arc<AtomicU64>| spawn(move || c.fetch_add(1, Ordering::SeqCst));
            let a = mk(Arc::clone(&c));
            let b = mk(Arc::clone(&c));
            a.join().unwrap();
            b.join().unwrap();
            assert_eq!(c.load(Ordering::SeqCst), 2);
        });
        assert!(report.violation.is_none(), "{:?}", report.violation);
        assert!(report.complete);
        assert!(report.executions > 1, "explorer found no nondeterminism to explore");
    }

    #[test]
    fn mutex_counter_is_clean() {
        check(|| {
            let c = Arc::new(Mutex::new(0u64));
            let mk = |c: Arc<Mutex<u64>>| {
                spawn(move || {
                    let mut g = c.lock().unwrap();
                    *g += 1;
                })
            };
            let a = mk(Arc::clone(&c));
            let b = mk(Arc::clone(&c));
            a.join().unwrap();
            b.join().unwrap();
            assert_eq!(*c.lock().unwrap(), 2);
        });
    }

    #[test]
    fn detects_abba_deadlock() {
        let report = Checker::default().run(|| {
            let m1 = Arc::new(Mutex::new(()));
            let m2 = Arc::new(Mutex::new(()));
            let (a1, a2) = (Arc::clone(&m1), Arc::clone(&m2));
            let t1 = spawn(move || {
                let _g1 = a1.lock().unwrap();
                let _g2 = a2.lock().unwrap();
            });
            let (b1, b2) = (Arc::clone(&m1), Arc::clone(&m2));
            let t2 = spawn(move || {
                let _g2 = b2.lock().unwrap();
                let _g1 = b1.lock().unwrap();
            });
            let _ = t1.join();
            let _ = t2.join();
        });
        let v = report.violation.expect("ABBA lock order must deadlock in some schedule");
        assert!(v.message.contains("deadlock"), "unexpected violation: {}", v.message);
    }

    /// `wait_timeout` wakes with `timed_out() == true` at quiescence
    /// when nobody will ever notify.
    #[test]
    fn condvar_timeout_fires_at_quiescence() {
        check(|| {
            let q = Arc::new((Mutex::new(false), Condvar::new()));
            let q2 = Arc::clone(&q);
            let t = spawn(move || {
                let (lock, cv) = &*q2;
                let mut ready = lock.lock().unwrap();
                let mut fired = false;
                while !*ready {
                    let (g, res) = cv.wait_timeout(ready, Duration::from_millis(1)).unwrap();
                    ready = g;
                    if res.timed_out() {
                        fired = true;
                        break;
                    }
                }
                assert!(fired, "nobody notifies, so only the timeout can wake us");
            });
            t.join().unwrap();
        });
    }

    /// Classic flag+condvar handoff: no lost wakeup in any schedule
    /// (the notify may land before or after the wait).
    #[test]
    fn condvar_notify_handoff_is_clean() {
        check(|| {
            let q = Arc::new((Mutex::new(false), Condvar::new()));
            let q2 = Arc::clone(&q);
            let waiter = spawn(move || {
                let (lock, cv) = &*q2;
                let mut ready = lock.lock().unwrap();
                while !*ready {
                    ready = cv.wait(ready).unwrap();
                }
            });
            let (lock, cv) = &*q;
            {
                let mut ready = lock.lock().unwrap();
                *ready = true;
            }
            cv.notify_one();
            waiter.join().unwrap();
        });
    }
}
