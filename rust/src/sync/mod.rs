//! Concurrency shim: the one gate between this crate and `std::sync`.
//!
//! Every concurrent data structure in the crate (the lock-free front
//! end, the batcher clocks, the replication runner, the serving
//! leader's credit queue) imports its atomics, locks and condvars from
//! here instead of `std::sync` — a rule enforced mechanically by
//! `srclint` (`raw-sync`: no `std::sync::` outside `src/sync/`, with
//! `std::sync::mpsc` exempted since channels need no instrumentation
//! for the protocols we check).
//!
//! * **Normal builds** (no `model` feature): every name below is a
//!   plain re-export of the `std` type.  The shim compiles to nothing —
//!   zero cost, byte-for-byte the types the code always used — which
//!   `tests` in this module pin with `TypeId` equality assertions.
//! * **`--features model` builds**: the same names resolve to the
//!   instrumented wrappers in [`model`], whose every operation is a
//!   scheduling point for the in-repo DFS model checker
//!   ([`model::Checker`]).  Outside a checker run the wrappers fall
//!   through to the real `std` primitives, so the full test suite still
//!   passes under the feature.
//!
//! ## Shim rules
//!
//! 1. Import `Atomic*`, `Mutex`, `Condvar`, `Arc`, `Ordering` from
//!    `crate::sync`, never from `std::sync` (lint: `raw-sync`).
//! 2. Every explicit memory `Ordering::*` argument carries an
//!    `// ordering:` rationale comment (lint: `ordering-rationale`) —
//!    the proof obligation lives next to the code it justifies.
//! 3. Protocols built on these types should have a bounded model in
//!    `tests/model_check.rs`; the checker explores sequentially
//!    consistent interleavings exhaustively (2–3 threads, preemption
//!    bound), while the weak-memory axis is covered by the Miri and
//!    ThreadSanitizer CI jobs.
//!
//! `Arc` is re-exported un-instrumented in both modes: the checker
//! models interleavings of operations, and `Arc`'s own refcounting is
//! `std`'s problem (Miri checks it).

#[cfg(feature = "model")]
pub mod model;

#[cfg(feature = "model")]
pub use model::{
    AtomicBool, AtomicI64, AtomicU64, AtomicUsize, Condvar, Mutex, MutexGuard,
    WaitTimeoutResult,
};

#[cfg(not(feature = "model"))]
pub use std::sync::atomic::{AtomicBool, AtomicI64, AtomicU64, AtomicUsize};
#[cfg(not(feature = "model"))]
pub use std::sync::{Condvar, Mutex, MutexGuard, WaitTimeoutResult};

pub use std::sync::atomic::Ordering;
pub use std::sync::Arc;

#[cfg(all(test, not(feature = "model")))]
mod tests {
    use std::any::TypeId;

    /// Satellite gate (shim transparency): in a non-`model` build the
    /// shim names ARE the `std` types — not newtypes, not wrappers —
    /// so the normal-build hot paths cannot pay a single instruction
    /// for the existence of the model checker.
    #[test]
    fn non_model_shim_is_exactly_std() {
        assert_eq!(
            TypeId::of::<crate::sync::AtomicU64>(),
            TypeId::of::<std::sync::atomic::AtomicU64>()
        );
        assert_eq!(
            TypeId::of::<crate::sync::AtomicI64>(),
            TypeId::of::<std::sync::atomic::AtomicI64>()
        );
        assert_eq!(
            TypeId::of::<crate::sync::AtomicBool>(),
            TypeId::of::<std::sync::atomic::AtomicBool>()
        );
        assert_eq!(
            TypeId::of::<crate::sync::AtomicUsize>(),
            TypeId::of::<std::sync::atomic::AtomicUsize>()
        );
        assert_eq!(
            TypeId::of::<crate::sync::Mutex<u64>>(),
            TypeId::of::<std::sync::Mutex<u64>>()
        );
        assert_eq!(
            TypeId::of::<crate::sync::Condvar>(),
            TypeId::of::<std::sync::Condvar>()
        );
        assert_eq!(
            TypeId::of::<crate::sync::Arc<u64>>(),
            TypeId::of::<std::sync::Arc<u64>>()
        );
    }
}
