//! **End-to-end driver**: the full three-layer system on a real workload.
//!
//! This is the repo's proof that all layers compose:
//!
//!   L1  Pallas kernels (sort network, NN forward)  — AOT-lowered once
//!   L2  JAX entry points                           — `artifacts/*.hlo.txt`
//!   L3  Rust: calibration → rate measurement (Table 3) → CAB/GrIn/LB
//!       scheduling of N = 20 closed-loop programs over FCFS device
//!       queues, every task executing a *real* PJRT kernel.
//!
//! Reproduces the §7.3 P2-biased experiment at one η and reports the
//! paper's headline comparison (CAB vs LB vs BF vs theory).
//!
//! ```bash
//! make artifacts && cargo run --release --example cpu_gpu_platform
//! ```

use hetsched::model::throughput::x_max_theoretical;
use hetsched::platform::bench_rig::{cases, run_platform, PlatformConfig};
use hetsched::platform::{calibrate, measure_rates};
use hetsched::policy::PolicyKind;
use hetsched::report::Table;
use hetsched::sim::workload;

fn main() -> hetsched::Result<()> {
    println!("== hetsched end-to-end driver (paper §7.3, P2-biased) ==\n");

    // Offline phase, exactly as the paper: calibrate kernel baselines,
    // build the device set, measure the affinity matrix (Table 3).
    println!("[1/3] calibrating kernel baselines on the PJRT CPU client...");
    let cal = calibrate(5)?;
    let devices = cases::p2_biased(&cal, 96);
    println!(
        "      reps: CPU {:?}, GPU {:?}",
        devices[0].reps, devices[1].reps
    );

    println!("[2/3] measuring processing rates (Table 3 analog)...");
    let rates = measure_rates(&devices, 3)?;
    let mut t3 = Table::new("measured rates (tasks/s)", &["benchmark", "CPU", "GPU"]);
    for (i, name) in ["quicksort-1000 (sort_large)", "NN-2000 (nn)"].iter().enumerate() {
        t3.row(vec![
            name.to_string(),
            format!("{:.2}", rates.mu.rate(i, 0)),
            format!("{:.2}", rates.mu.rate(i, 1)),
        ]);
    }
    t3.print();
    let regime = rates.mu.classify()?;
    println!("      regime: {} (paper: P2-biased)\n", regime.name());

    // Online phase: N = 20 closed-loop programs, η = 0.5.
    println!("[3/3] running N = 20 closed-loop programs per policy...");
    let (n1, n2) = workload::split_populations(20, 0.5);
    let theory = x_max_theoretical(&rates.mu, regime, n1, n2);
    let mut t = Table::new(
        "experimental throughput (η = 0.5)",
        &["policy", "X (tasks/s)", "E[T] (ms)", "vs theory"],
    );
    let mut lb_x = 0.0;
    let mut cab_x = 0.0;
    for kind in [PolicyKind::Cab, PolicyKind::BestFit, PolicyKind::Jsq, PolicyKind::LoadBalance] {
        let cfg = PlatformConfig {
            devices: devices.clone(),
            populations: vec![n1, n2],
            warmup: 20,
            measure: 60,
            seed: 2017,
        };
        let mut p = kind.build();
        let r = run_platform(&cfg, &rates, p.as_mut())?;
        if kind == PolicyKind::LoadBalance {
            lb_x = r.throughput;
        }
        if kind == PolicyKind::Cab {
            cab_x = r.throughput;
        }
        t.row(vec![
            kind.name().into(),
            format!("{:.2}", r.throughput),
            format!("{:.1}", r.mean_response_s * 1e3),
            format!("{:.0}%", 100.0 * r.throughput / theory),
        ]);
    }
    t.print();
    println!("theory (Eq. 17 from measured rates): {theory:.2} tasks/s");
    println!(
        "CAB vs LB: {:.2}x (paper band for this case: 3.27x–9.07x)",
        cab_x / lb_x
    );
    Ok(())
}
