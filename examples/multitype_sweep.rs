//! Many processor types: GrIn vs the field on randomized k×l systems —
//! the §6 scenario as a library consumer would script it.
//!
//! ```bash
//! cargo run --release --example multitype_sweep -- --types 4 --procs 5
//! ```

use hetsched::cli::Args;
use hetsched::policy::{grin, PolicyKind};
use hetsched::report::Table;
use hetsched::sim::distribution::Distribution;
use hetsched::sim::engine::{ClosedNetwork, SimConfig};
use hetsched::sim::rng::Rng;
use hetsched::sim::workload;
use hetsched::solver::exhaustive::ExhaustiveSolver;
use hetsched::solver::slsqp::Slsqp;

fn main() -> hetsched::Result<()> {
    let args = Args::from_env()?;
    let k: usize = args.get_parse("types", 3)?;
    let l: usize = args.get_parse("procs", 3)?;
    let seed: u64 = args.get_parse("seed", 42)?;
    args.finish()?;

    let mut rng = Rng::new(seed);
    let mu = workload::random_mu(&mut rng, k, l, 0.5, 30.0)?;
    let pops = workload::random_populations(&mut rng, k, 7);
    println!("random {k}x{l} system, populations {pops:?}");

    // Solver view: GrIn vs SLSQP vs (small systems) exhaustive.
    let g = grin::solve(&mu, &pops)?;
    println!("GrIn : X = {:.4} ({} moves)\n{}", g.throughput, g.moves, g.state);
    let s = Slsqp::default().solve(&mu, &pops)?;
    println!(
        "SLSQP: X = {:.4} (continuous, {} iters, converged: {})",
        s.throughput, s.iterations, s.converged
    );
    let states = ExhaustiveSolver::state_count(&pops, l);
    if states <= 2_000_000 {
        let o = ExhaustiveSolver.solve(&mu, &pops)?;
        println!(
            "Opt  : X = {:.4} over {} states — GrIn gap {:.2}%",
            o.throughput,
            o.evaluated,
            100.0 * (1.0 - g.throughput / o.throughput)
        );
    } else {
        println!("Opt  : skipped ({states} states)");
    }

    // Simulation view: all six policies on the same system.
    let mut t = Table::new(
        "simulated metrics (exponential sizes)",
        &["policy", "X", "E[T]", "EDP"],
    );
    for kind in PolicyKind::six_multi_type() {
        if kind == PolicyKind::Opt && states > 2_000_000 {
            continue;
        }
        let mut cfg = SimConfig::paper_default(pops.clone());
        cfg.dist = Distribution::Exponential;
        cfg.measure = 10_000;
        let net = ClosedNetwork::new(&mu, cfg)?;
        let r = net.run(kind.build().as_mut())?;
        t.row(vec![
            kind.name().into(),
            format!("{:.4}", r.throughput),
            format!("{:.4}", r.mean_response),
            format!("{:.4}", r.edp),
        ]);
    }
    t.print();
    Ok(())
}
