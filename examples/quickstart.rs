//! Quickstart: classify a system, solve for the optimal schedule, and
//! simulate it against load balancing — in ~40 lines.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use hetsched::prelude::*;

fn main() -> Result<()> {
    // A CPU+GPU system: task type 0 is CPU-affine, type 1 GPU-affine,
    // but type-0 tasks are faster *everywhere* (the paper's P1-biased
    // simulation matrix).
    let mu = AffinityMatrix::two_type(20.0, 15.0, 3.0, 8.0)?;

    // 1. CAB classifies the system from the μ orderings alone…
    let regime = mu.classify()?;
    println!("regime: {} -> CAB plays {}", regime.name(),
             if regime.is_biased() { "Accelerate-the-Fastest" } else { "Best-Fit" });

    // 2. …and GrIn solves the general integer program (identical to CAB
    //    on two processor types).
    let solution = policy::grin::solve(&mu, &[10, 10])?;
    println!("optimal state (X = {:.3} tasks/s):\n{}", solution.throughput, solution.state);

    // 3. Simulate the closed system (N = 20 programs, PS processors,
    //    exponential task sizes) under CAB and under load balancing.
    let cfg = SimConfig::paper_default(vec![10, 10]);
    for kind in [PolicyKind::Cab, PolicyKind::LoadBalance] {
        let net = ClosedNetwork::new(&mu, cfg.clone())?;
        let r = net.run(kind.build().as_mut())?;
        println!(
            "{:<4} X = {:.3} tasks/s   E[T] = {:.3} s   EDP = {:.3}   X·E[T] = {:.2}",
            kind.name(),
            r.throughput,
            r.mean_response,
            r.edp,
            r.little_product
        );
    }
    Ok(())
}
