//! Serving demo: the coordinator (router + dynamic batcher + PJRT
//! workers) under different placement policies.
//!
//! Shows the paper's policies driving a live, batched serving system:
//! closed-loop clients issue sort- and NN-class requests; the router
//! places them with CAB / JSQ / LB; NN requests coalesce into 8-row
//! `nn_small` kernel launches.  Reports throughput and latency
//! percentiles per policy.
//!
//! ```bash
//! make artifacts && cargo run --release --example serving_router
//! ```

use hetsched::coordinator::{Coordinator, ServeConfig};
use hetsched::policy::PolicyKind;
use hetsched::report::Table;

fn main() -> hetsched::Result<()> {
    let mut t = Table::new(
        "serving comparison (400 requests, 16 in flight, 50% sort / 50% NN)",
        &["policy", "req/s", "sort p50 ms", "sort p99 ms", "nn p50 ms", "nn p99 ms", "batches", "fill"],
    );
    for kind in [PolicyKind::Cab, PolicyKind::Jsq, PolicyKind::LoadBalance] {
        let cfg = ServeConfig {
            policy: kind,
            total: 400,
            inflight: 16,
            ..Default::default()
        };
        let r = Coordinator::run(&cfg)?;
        t.row(vec![
            kind.name().into(),
            format!("{:.0}", r.rps),
            format!("{:.2}", r.sort_latency.quantile_s(0.5) * 1e3),
            format!("{:.2}", r.sort_latency.quantile_s(0.99) * 1e3),
            format!("{:.2}", r.nn_latency.quantile_s(0.5) * 1e3),
            format!("{:.2}", r.nn_latency.quantile_s(0.99) * 1e3),
            r.batches.to_string(),
            format!("{:.2}", r.batch_fill),
        ]);
    }
    t.print();
    println!("(batch fill = mean requests per nn_small launch / 8)");
    Ok(())
}
